//! Solvers (optimizers). API mirrors `nnabla.solvers`:
//! `set_parameters`, `zero_grad`, `update`, `weight_decay`,
//! `scale_grad`, `check_inf_or_nan_grad` (the last two are the
//! mixed-precision hooks of Listing 6).
//!
//! The solver always *updates in FP-32* on the f32 compute buffer and
//! re-quantizes into the parameter's storage dtype afterwards — the
//! paper's "update is performed in FP-32, although the weights are
//! managed in both FP-16 and 32" (§3.3).

pub mod algos;
pub mod schedulers;

pub use algos::{AdaDelta, AdaGrad, Adam, AdamW, Lars, Momentum, Nesterov, RmsProp, Sgd};

use crate::graph::Variable;
use crate::tensor::NdArray;
use std::collections::HashMap;

/// An optimization algorithm: updates one parameter tensor given its
/// gradient and per-parameter state slots.
pub trait Algorithm {
    /// Display name (NNP Optimizer records, Console trials).
    fn name(&self) -> &'static str;
    /// Number of state arrays per parameter (e.g. Adam: m and v).
    fn n_states(&self) -> usize;
    /// Current learning rate.
    fn learning_rate(&self) -> f32;
    /// Set the learning rate (schedules).
    fn set_learning_rate(&mut self, lr: f32);
    /// Apply one update step. `t` is the 1-based step count.
    fn update_one(&self, t: usize, data: &mut [f32], grad: &[f32], states: &mut [NdArray]);
}

/// A solver bound to a set of named parameters.
pub struct Solver {
    algo: Box<dyn Algorithm>,
    params: Vec<(String, Variable)>,
    states: HashMap<String, Vec<NdArray>>,
    t: usize,
}

impl Solver {
    pub fn new(algo: Box<dyn Algorithm>) -> Self {
        Solver { algo, params: Vec::new(), states: HashMap::new(), t: 0 }
    }

    /// Convenience constructors matching `nnabla.solvers.*`.
    pub fn sgd(lr: f32) -> Self {
        Self::new(Box::new(Sgd { lr }))
    }
    pub fn momentum(lr: f32, momentum: f32) -> Self {
        Self::new(Box::new(Momentum { lr, momentum }))
    }
    pub fn nesterov(lr: f32, momentum: f32) -> Self {
        Self::new(Box::new(Nesterov { lr, momentum }))
    }
    pub fn adam(alpha: f32, beta1: f32, beta2: f32, eps: f32) -> Self {
        Self::new(Box::new(Adam { alpha, beta1, beta2, eps }))
    }
    pub fn adamw(alpha: f32, beta1: f32, beta2: f32, eps: f32, wd: f32) -> Self {
        Self::new(Box::new(AdamW { alpha, beta1, beta2, eps, wd }))
    }
    pub fn adagrad(lr: f32, eps: f32) -> Self {
        Self::new(Box::new(AdaGrad { lr, eps }))
    }
    pub fn adadelta(lr: f32, decay: f32, eps: f32) -> Self {
        Self::new(Box::new(AdaDelta { lr, decay, eps }))
    }
    pub fn rmsprop(lr: f32, decay: f32, eps: f32) -> Self {
        Self::new(Box::new(RmsProp { lr, decay, eps }))
    }
    pub fn lars(lr: f32, momentum: f32, coeff: f32, eps: f32) -> Self {
        Self::new(Box::new(Lars { lr, momentum, coeff, eps }))
    }

    /// Bind parameters (only `need_grad` ones are updated).
    pub fn set_parameters(&mut self, params: &[(String, Variable)]) {
        self.params =
            params.iter().filter(|(_, v)| v.need_grad()).map(|(n, v)| (n.clone(), v.clone())).collect();
        // (re)allocate states lazily on first update to tolerate shape changes
        self.states.clear();
        self.t = 0;
    }

    pub fn parameters(&self) -> &[(String, Variable)] {
        &self.params
    }

    pub fn algorithm_name(&self) -> &'static str {
        self.algo.name()
    }

    pub fn learning_rate(&self) -> f32 {
        self.algo.learning_rate()
    }

    pub fn set_learning_rate(&mut self, lr: f32) {
        self.algo.set_learning_rate(lr);
    }

    /// Clear all bound gradients (`solver.zero_grad()`).
    pub fn zero_grad(&self) {
        for (_, v) in &self.params {
            v.zero_grad();
        }
    }

    /// Add `lambda * w` to each gradient (L2 weight decay,
    /// `solver.weight_decay(lambda)`).
    pub fn weight_decay(&self, lambda: f32) {
        if lambda == 0.0 {
            return;
        }
        for (_, v) in &self.params {
            let g = v.grad();
            let w = v.data();
            let new: Vec<f32> =
                g.data().iter().zip(w.data()).map(|(&g, &w)| g + lambda * w).collect();
            v.set_grad(NdArray::from_vec(g.dims(), new));
        }
    }

    /// Multiply every gradient by `s` — `solver.scale_grad(1/loss_scale)`
    /// from Listing 6.
    pub fn scale_grad(&self, s: f32) {
        for (_, v) in &self.params {
            let g = v.grad();
            v.set_grad(crate::tensor::ops::scale(&g, s));
        }
    }

    /// Global-norm gradient clipping.
    pub fn clip_grad_by_norm(&self, max_norm: f32) {
        let mut sq = 0.0f64;
        for (_, v) in &self.params {
            let g = v.grad();
            sq += g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
        let norm = sq.sqrt() as f32;
        if norm > max_norm {
            let s = max_norm / norm;
            self.scale_grad(s);
        }
    }

    /// True if any bound gradient contains Inf or NaN —
    /// `solver.check_inf_or_nan_grad()` from Listing 6.
    pub fn check_inf_or_nan_grad(&self) -> bool {
        self.params.iter().any(|(_, v)| v.grad().has_inf_or_nan())
    }

    /// Apply one optimization step (`solver.update()`). Updates run in
    /// f32 and are re-quantized to each parameter's storage dtype.
    pub fn update(&mut self) {
        self.t += 1;
        for (name, v) in &self.params {
            let grad = v.grad();
            let mut data = v.data();
            let dims = data.dims().to_vec();
            let states = self.states.entry(name.clone()).or_insert_with(|| {
                (0..self.algo.n_states()).map(|_| NdArray::zeros(&dims)).collect()
            });
            self.algo.update_one(self.t, data.data_mut(), grad.data(), states);
            data.requantize(); // enforce storage dtype (half simulation)
            v.set_data(data);
        }
    }

    /// Steps taken so far.
    pub fn step_count(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_param(init: f32) -> (String, Variable) {
        ("w".to_string(), Variable::from_array(NdArray::full(&[1], init), true))
    }

    /// Minimize f(w) = w^2 with each solver; all must reach ~0.
    fn converges(mut solver: Solver, steps: usize, tol: f32) {
        let algo = solver.algorithm_name();
        let (name, w) = quad_param(5.0);
        solver.set_parameters(&[(name, w.clone())]);
        for _ in 0..steps {
            solver.zero_grad();
            let wv = w.data().item();
            w.set_grad(NdArray::full(&[1], 2.0 * wv)); // df/dw
            solver.update();
        }
        let final_w = w.data().item().abs();
        assert!(final_w < tol, "{algo}: final |w| = {final_w}");
    }

    #[test]
    fn all_solvers_minimize_quadratic() {
        converges(Solver::sgd(0.1), 100, 1e-3);
        converges(Solver::momentum(0.05, 0.9), 500, 5e-2);
        converges(Solver::nesterov(0.05, 0.9), 500, 5e-2);
        converges(Solver::adam(0.1, 0.9, 0.999, 1e-8), 300, 1e-2);
        converges(Solver::adamw(0.1, 0.9, 0.999, 1e-8, 0.0), 300, 1e-2);
        converges(Solver::adagrad(0.5, 1e-8), 400, 1e-2);
        converges(Solver::adadelta(1.0, 0.95, 1e-6), 2000, 2e-1);
        // rmsprop takes ~lr-sized (sign-like) steps near the optimum,
        // so it hovers within O(lr) of 0
        converges(Solver::rmsprop(0.05, 0.9, 1e-8), 400, 6e-2);
        // LARS steps are proportional to |w| (multiplicative decay on
        // this toy problem): check monotone progress, not a fixed tol
        converges(Solver::lars(0.5, 0.9, 0.05, 1e-9), 800, 2.5);
    }

    #[test]
    fn sgd_exact_step() {
        let mut s = Solver::sgd(0.5);
        let w = Variable::from_array(NdArray::full(&[2], 1.0), true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::from_slice(&[2], &[2.0, -4.0]));
        s.update();
        assert_eq!(w.data().data(), &[0.0, 3.0]);
    }

    #[test]
    fn weight_decay_adds_lambda_w() {
        let s = Solver::sgd(0.1);
        let mut s = s;
        let w = Variable::from_array(NdArray::full(&[1], 2.0), true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::full(&[1], 1.0));
        s.weight_decay(0.5);
        assert_eq!(w.grad().item(), 2.0); // 1 + 0.5*2
    }

    #[test]
    fn scale_grad_and_inf_check() {
        let mut s = Solver::sgd(0.1);
        let w = Variable::from_array(NdArray::full(&[1], 1.0), true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::full(&[1], 8.0));
        s.scale_grad(0.125);
        assert_eq!(w.grad().item(), 1.0);
        assert!(!s.check_inf_or_nan_grad());
        w.set_grad(NdArray::full(&[1], f32::INFINITY));
        assert!(s.check_inf_or_nan_grad());
    }

    #[test]
    fn clip_grad_by_norm_caps() {
        let mut s = Solver::sgd(0.1);
        let w = Variable::from_array(NdArray::zeros(&[2]), true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::from_slice(&[2], &[3.0, 4.0])); // norm 5
        s.clip_grad_by_norm(1.0);
        assert!((w.grad().norm2() - 1.0).abs() < 1e-5);
        // under the cap: untouched
        w.set_grad(NdArray::from_slice(&[2], &[0.3, 0.4]));
        s.clip_grad_by_norm(1.0);
        assert!((w.grad().norm2() - 0.5).abs() < 1e-5);
    }

    #[test]
    fn skips_non_trainable_params() {
        let mut s = Solver::sgd(0.1);
        let w = Variable::from_array(NdArray::full(&[1], 1.0), true);
        let frozen = Variable::from_array(NdArray::full(&[1], 1.0), false);
        s.set_parameters(&[("w".into(), w.clone()), ("frozen".into(), frozen.clone())]);
        assert_eq!(s.parameters().len(), 1);
        w.set_grad(NdArray::full(&[1], 1.0));
        s.update();
        assert_eq!(frozen.data().item(), 1.0);
    }

    #[test]
    fn half_storage_requantized_after_update() {
        use crate::tensor::DType;
        let mut s = Solver::sgd(1.0);
        let mut init = NdArray::full(&[1], 1.0);
        init.set_dtype(DType::BF16);
        let w = Variable::from_array(init, true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::full(&[1], 2f32.powi(-12))); // step below bf16 resolution at 1.0
        s.update();
        // 1.0 - 2^-12 rounds back to 1.0 in bf16 storage
        assert_eq!(w.data().item(), 1.0);
        assert_eq!(w.data().dtype(), DType::BF16);
    }
}
