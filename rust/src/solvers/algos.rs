//! The optimization algorithms behind [`crate::solvers::Solver`].

use super::Algorithm;
use crate::tensor::NdArray;

/// Vanilla stochastic gradient descent.
pub struct Sgd {
    pub lr: f32,
}

impl Algorithm for Sgd {
    fn name(&self) -> &'static str {
        "Sgd"
    }
    fn n_states(&self) -> usize {
        0
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], _s: &mut [NdArray]) {
        for (d, &g) in data.iter_mut().zip(grad) {
            *d -= self.lr * g;
        }
    }
}

/// Classical momentum (heavy ball).
pub struct Momentum {
    pub lr: f32,
    pub momentum: f32,
}

impl Algorithm for Momentum {
    fn name(&self) -> &'static str {
        "Momentum"
    }
    fn n_states(&self) -> usize {
        1
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let v = s[0].data_mut();
        for i in 0..data.len() {
            v[i] = self.momentum * v[i] - self.lr * grad[i];
            data[i] += v[i];
        }
    }
}

/// Nesterov accelerated gradient.
pub struct Nesterov {
    pub lr: f32,
    pub momentum: f32,
}

impl Algorithm for Nesterov {
    fn name(&self) -> &'static str {
        "Nesterov"
    }
    fn n_states(&self) -> usize {
        1
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let v = s[0].data_mut();
        for i in 0..data.len() {
            let v_prev = v[i];
            v[i] = self.momentum * v[i] - self.lr * grad[i];
            data[i] += -self.momentum * v_prev + (1.0 + self.momentum) * v[i];
        }
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    pub alpha: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Algorithm for Adam {
    fn name(&self) -> &'static str {
        "Adam"
    }
    fn n_states(&self) -> usize {
        2
    }
    fn learning_rate(&self) -> f32 {
        self.alpha
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.alpha = lr;
    }
    fn update_one(&self, t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let (m_arr, v_arr) = s.split_at_mut(1);
        let m = m_arr[0].data_mut();
        let v = v_arr[0].data_mut();
        let bc1 = 1.0 - self.beta1.powi(t as i32);
        let bc2 = 1.0 - self.beta2.powi(t as i32);
        let alpha_t = self.alpha * bc2.sqrt() / bc1;
        for i in 0..data.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            data[i] -= alpha_t * m[i] / (v[i].sqrt() + self.eps);
        }
    }
}

/// AdamW: Adam with decoupled weight decay.
pub struct AdamW {
    pub alpha: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub wd: f32,
}

impl Algorithm for AdamW {
    fn name(&self) -> &'static str {
        "AdamW"
    }
    fn n_states(&self) -> usize {
        2
    }
    fn learning_rate(&self) -> f32 {
        self.alpha
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.alpha = lr;
    }
    fn update_one(&self, t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let (m_arr, v_arr) = s.split_at_mut(1);
        let m = m_arr[0].data_mut();
        let v = v_arr[0].data_mut();
        let bc1 = 1.0 - self.beta1.powi(t as i32);
        let bc2 = 1.0 - self.beta2.powi(t as i32);
        let alpha_t = self.alpha * bc2.sqrt() / bc1;
        for i in 0..data.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            data[i] -= alpha_t * m[i] / (v[i].sqrt() + self.eps) + self.alpha * self.wd * data[i];
        }
    }
}

/// AdaGrad.
pub struct AdaGrad {
    pub lr: f32,
    pub eps: f32,
}

impl Algorithm for AdaGrad {
    fn name(&self) -> &'static str {
        "AdaGrad"
    }
    fn n_states(&self) -> usize {
        1
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let h = s[0].data_mut();
        for i in 0..data.len() {
            h[i] += grad[i] * grad[i];
            data[i] -= self.lr * grad[i] / (h[i].sqrt() + self.eps);
        }
    }
}

/// AdaDelta (Zeiler).
pub struct AdaDelta {
    pub lr: f32,
    pub decay: f32,
    pub eps: f32,
}

impl Algorithm for AdaDelta {
    fn name(&self) -> &'static str {
        "AdaDelta"
    }
    fn n_states(&self) -> usize {
        2
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let (e_g, e_dx) = s.split_at_mut(1);
        let eg = e_g[0].data_mut();
        let edx = e_dx[0].data_mut();
        for i in 0..data.len() {
            eg[i] = self.decay * eg[i] + (1.0 - self.decay) * grad[i] * grad[i];
            let dx = -((edx[i] + self.eps).sqrt() / (eg[i] + self.eps).sqrt()) * grad[i];
            edx[i] = self.decay * edx[i] + (1.0 - self.decay) * dx * dx;
            data[i] += self.lr * dx;
        }
    }
}

/// RMSprop.
pub struct RmsProp {
    pub lr: f32,
    pub decay: f32,
    pub eps: f32,
}

impl Algorithm for RmsProp {
    fn name(&self) -> &'static str {
        "RmsProp"
    }
    fn n_states(&self) -> usize {
        1
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let h = s[0].data_mut();
        for i in 0..data.len() {
            h[i] = self.decay * h[i] + (1.0 - self.decay) * grad[i] * grad[i];
            data[i] -= self.lr * grad[i] / (h[i].sqrt() + self.eps);
        }
    }
}

/// LARS — layer-wise adaptive rate scaling (large-batch distributed
/// training, the regime of the paper's §4 experiments).
pub struct Lars {
    pub lr: f32,
    pub momentum: f32,
    pub coeff: f32,
    pub eps: f32,
}

impl Algorithm for Lars {
    fn name(&self) -> &'static str {
        "Lars"
    }
    fn n_states(&self) -> usize {
        1
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn update_one(&self, _t: usize, data: &mut [f32], grad: &[f32], s: &mut [NdArray]) {
        let w_norm = data.iter().map(|v| v * v).sum::<f32>().sqrt();
        let g_norm = grad.iter().map(|v| v * v).sum::<f32>().sqrt();
        let local_lr = if w_norm > 0.0 && g_norm > 0.0 {
            self.coeff * w_norm / (g_norm + self.eps)
        } else {
            1.0
        };
        let v = s[0].data_mut();
        for i in 0..data.len() {
            v[i] = self.momentum * v[i] - self.lr * local_lr * grad[i];
            data[i] += v[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_steps(algo: &dyn Algorithm, w0: f32, grads: &[f32]) -> f32 {
        let mut data = vec![w0];
        let mut states: Vec<NdArray> =
            (0..algo.n_states()).map(|_| NdArray::zeros(&[1])).collect();
        for (t, &g) in grads.iter().enumerate() {
            algo.update_one(t + 1, &mut data, &[g], &mut states);
        }
        data[0]
    }

    #[test]
    fn sgd_formula() {
        assert!((run_steps(&Sgd { lr: 0.1 }, 1.0, &[1.0, 1.0]) - 0.8).abs() < 1e-6);
    }

    #[test]
    fn momentum_accelerates_constant_grad() {
        // with constant gradient, momentum's total step exceeds sgd's
        let sgd_w = run_steps(&Sgd { lr: 0.1 }, 0.0, &[1.0; 10]);
        let mom_w = run_steps(&Momentum { lr: 0.1, momentum: 0.9 }, 0.0, &[1.0; 10]);
        assert!(mom_w < sgd_w);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first step| == alpha regardless of grad scale
        for g in [1e-4f32, 1.0, 1e4] {
            let w = run_steps(&Adam { alpha: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-12 }, 0.0, &[g]);
            assert!((w.abs() - 0.1).abs() < 1e-4, "g={g} -> w={w}");
        }
    }

    #[test]
    fn adamw_decays_weight_without_gradient() {
        let w = run_steps(
            &AdamW { alpha: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.5 },
            1.0,
            &[0.0],
        );
        assert!((w - 0.95).abs() < 1e-5); // only decoupled decay acts
    }

    #[test]
    fn adagrad_steps_shrink() {
        let a = AdaGrad { lr: 0.1, eps: 1e-12 };
        let w1 = run_steps(&a, 0.0, &[1.0]);
        let w2 = run_steps(&a, 0.0, &[1.0, 1.0]);
        let step1 = -w1;
        let step2 = -(w2 - w1);
        assert!(step2 < step1);
    }

    #[test]
    fn rmsprop_normalizes_gradient_scale() {
        let a = RmsProp { lr: 0.01, decay: 0.9, eps: 1e-12 };
        let small = run_steps(&a, 0.0, &[1e-3]).abs();
        let large = run_steps(&a, 0.0, &[1e3]).abs();
        assert!((small - large).abs() / large < 1e-3);
    }

    #[test]
    fn lars_scales_with_weight_norm() {
        let a = Lars { lr: 0.1, momentum: 0.0, coeff: 0.01, eps: 1e-9 };
        // same gradient, bigger weight -> bigger step
        let s_small = (run_steps(&a, 0.1, &[1.0]) - 0.1).abs();
        let s_large = (run_steps(&a, 10.0, &[1.0]) - 10.0).abs();
        assert!(s_large > s_small * 50.0);
    }
}
