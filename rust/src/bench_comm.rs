//! Distributed-training benchmark harness — shared by `nnl bench-comm`
//! and the CI smoke, emitting `BENCH_comm.json`.
//!
//! Trains the same lenet job at world 1/2/4 over the in-process thread
//! backend with backward/reduce overlap on and off, plus TCP-backend
//! runs (f32 and fp16 wire) over loopback sockets, and reports per-run
//! steps/s alongside the `monitor::metrics` comm counters (all-reduce
//! calls, bytes moved, overlap time hidden, ring stalls). The
//! acceptance number is `overlap_no_worse`: firing bucket all-reduces
//! from the backward hook must not lose throughput against the
//! queue-everything-after-backward baseline (0.9 slack absorbs
//! scheduler noise on loaded CI hosts — every run computes
//! bit-identical updates, so throughput is the only axis).

use crate::comm::{NetCommunicator, NetOptions};
use crate::data::SyntheticImages;
use crate::monitor::metrics::{self, CommSnapshot};
use crate::tensor::parallel;
use crate::trainer::{
    train_distributed_opts, train_worker, DistConfig, TrainConfig, TrainReport,
};
use crate::utils::json::Json;

/// Everything one run produces: the human table and the JSON payload.
pub struct CommBenchReport {
    pub text: String,
    pub json: Json,
}

struct RunStats {
    label: &'static str,
    backend: &'static str,
    world: usize,
    overlap: bool,
    fp16: bool,
    steps_per_s: f64,
    final_loss: f32,
    comm: CommSnapshot,
}

fn bench_cfg(quick: bool) -> TrainConfig {
    TrainConfig {
        steps: if quick { 4 } else { 12 },
        val_batches: 1,
        ..Default::default()
    }
}

/// One TCP-backend job over loopback: rank 0 in this thread via the
/// pre-bound listener, other ranks on worker threads dialing it —
/// the same wiring `nnl train-dist --launch` does across processes.
fn run_tcp(
    data: &SyntheticImages,
    cfg: &TrainConfig,
    dist: &DistConfig,
    world: usize,
    fp16: bool,
) -> TrainReport {
    let listener = NetCommunicator::rendezvous_bind("127.0.0.1:0").expect("bench bind");
    let addr = listener.local_addr().expect("listener addr").to_string();
    let opts = NetOptions { fp16_wire: fp16, ..NetOptions::default() };
    let mut handles = Vec::new();
    for rank in 1..world {
        let addr = addr.clone();
        let opts = opts.clone();
        let data = data.clone();
        let cfg = cfg.clone();
        let dist = dist.clone();
        handles.push(std::thread::spawn(move || {
            let comm =
                NetCommunicator::connect(rank, world, &addr, opts).expect("bench connect");
            train_worker("lenet", &data, &cfg, &dist, comm, "cpu:tcp").expect("bench worker");
        }));
    }
    let comm =
        NetCommunicator::connect_with_listener(listener, world, opts).expect("bench rank 0");
    let report =
        train_worker("lenet", data, cfg, dist, comm, "cpu:tcp").expect("bench rank 0 worker");
    for h in handles {
        h.join().expect("bench worker thread");
    }
    report
}

/// Run the suite. `quick` shrinks step counts for CI smoke use.
pub fn run(quick: bool) -> CommBenchReport {
    let data = SyntheticImages::new(10, 1, 28, 8, 1);
    let cfg = bench_cfg(quick);
    // small buckets so even lenet produces several per step — the
    // overlap machinery is actually exercised, not bypassed
    let bucket_bytes = 64 * 1024;

    // (label, backend, world, overlap, fp16)
    let cases: [(&'static str, &'static str, usize, bool, bool); 7] = [
        ("threads w1", "threads", 1, true, false),
        ("threads w2 overlap", "threads", 2, true, false),
        ("threads w2 serial", "threads", 2, false, false),
        ("threads w4 overlap", "threads", 4, true, false),
        ("threads w4 serial", "threads", 4, false, false),
        ("tcp w2 f32", "tcp", 2, true, false),
        ("tcp w2 fp16", "tcp", 2, true, true),
    ];
    let mut runs: Vec<RunStats> = Vec::new();
    for &(label, backend, world, overlap, fp16) in &cases {
        let dist = DistConfig { bucket_bytes, overlap };
        let before = metrics::comm().snapshot();
        let report = if backend == "threads" {
            train_distributed_opts("lenet", data.clone(), &cfg, world, &dist)
                .expect("bench thread run")
        } else {
            run_tcp(&data, &cfg, &dist, world, fp16)
        };
        runs.push(RunStats {
            label,
            backend,
            world,
            overlap,
            fp16,
            steps_per_s: report.steps as f64 / report.wall_secs.max(1e-9),
            final_loss: report.final_loss(),
            comm: metrics::comm().snapshot().since(&before),
        });
    }

    let throughput = |overlap: bool| {
        runs.iter()
            .filter(|r| r.backend == "threads" && r.world > 1 && r.overlap == overlap)
            .map(|r| r.steps_per_s)
            .sum::<f64>()
    };
    let overlap_no_worse = throughput(true) >= 0.9 * throughput(false);
    let fp16_moves_fewer_bytes = {
        let bytes = |fp16: bool| {
            runs.iter()
                .find(|r| r.backend == "tcp" && r.fp16 == fp16)
                .map(|r| r.comm.bytes_sent)
                .unwrap_or(0)
        };
        bytes(true) < bytes(false)
    };

    let mut text = format!(
        "comm bench: lenet, {} steps/run, bucket {} KiB, NNL_THREADS={}\n\
         {:<20} {:>6} {:>9} {:>10} {:>12} {:>12} {:>11} {:>7}\n",
        cfg.steps,
        bucket_bytes / 1024,
        parallel::num_threads(),
        "run",
        "world",
        "steps/s",
        "loss",
        "bytes sent",
        "bytes recv",
        "hidden ms",
        "stalls",
    );
    for r in &runs {
        text.push_str(&format!(
            "{:<20} {:>6} {:>9.2} {:>10.4} {:>12} {:>12} {:>11.2} {:>7}\n",
            r.label,
            r.world,
            r.steps_per_s,
            r.final_loss,
            r.comm.bytes_sent,
            r.comm.bytes_recv,
            r.comm.overlap_ms_hidden,
            r.comm.ring_stalls,
        ));
    }
    text.push_str(&format!(
        "overlap_no_worse: {overlap_no_worse}   fp16_moves_fewer_bytes: {fp16_moves_fewer_bytes}\n"
    ));

    let totals = runs.iter().fold(
        (0u64, 0u64, 0u64, 0.0f64, 0u64),
        |(c, s, r0, h, st), r| {
            (
                c + r.comm.allreduce_calls,
                s + r.comm.bytes_sent,
                r0 + r.comm.bytes_recv,
                h + r.comm.overlap_ms_hidden,
                st + r.comm.ring_stalls,
            )
        },
    );
    let json = Json::obj(vec![
        ("nnl_threads", Json::num(parallel::num_threads() as f64)),
        ("model", Json::str("lenet")),
        ("steps", Json::num(cfg.steps as f64)),
        ("bucket_bytes", Json::num(bucket_bytes as f64)),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(r.label)),
                            ("backend", Json::str(r.backend)),
                            ("world", Json::num(r.world as f64)),
                            ("overlap", Json::Bool(r.overlap)),
                            ("fp16_wire", Json::Bool(r.fp16)),
                            ("steps_per_s", Json::num(r.steps_per_s)),
                            ("final_loss", Json::num(r.final_loss as f64)),
                            ("allreduce_calls", Json::num(r.comm.allreduce_calls as f64)),
                            ("bytes_sent", Json::num(r.comm.bytes_sent as f64)),
                            ("bytes_recv", Json::num(r.comm.bytes_recv as f64)),
                            ("overlap_ms_hidden", Json::num(r.comm.overlap_ms_hidden)),
                            ("ring_stalls", Json::num(r.comm.ring_stalls as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "totals",
            Json::obj(vec![
                ("allreduce_calls", Json::num(totals.0 as f64)),
                ("bytes_sent", Json::num(totals.1 as f64)),
                ("bytes_recv", Json::num(totals.2 as f64)),
                ("overlap_ms_hidden", Json::num(totals.3)),
                ("ring_stalls", Json::num(totals.4 as f64)),
            ]),
        ),
        ("overlap_no_worse", Json::Bool(overlap_no_worse)),
        ("fp16_moves_fewer_bytes", Json::Bool(fp16_moves_fewer_bytes)),
    ]);
    CommBenchReport { text, json }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_produces_sane_report() {
        let report = run(true);
        assert!(report.text.contains("overlap_no_worse"));
        let rendered = report.json.to_string_pretty();
        assert!(rendered.contains("\"runs\""), "{rendered}");
        assert!(rendered.contains("\"overlap_no_worse\""), "{rendered}");
        // the TCP runs really moved bytes through the ring
        assert!(rendered.contains("\"bytes_sent\""), "{rendered}");
    }
}
