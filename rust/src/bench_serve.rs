//! Serving front-end benchmark harness — shared by `nnl bench-serve
//! --net` and `benches/serve_net.rs`, emitting `BENCH_serve.json`.
//!
//! Measures the TCP front end under open-loop offered load: a
//! registry hosting the same zoo model three ways (f32 micro-batched,
//! f32 unbatched, int8 micro-batched), a real [`NetServer`] on a
//! loopback socket, and paced client threads driving the binary
//! protocol. Reports achieved rps and p50/p99 latency per offered
//! rate, plus shed/error counts — the acceptance number is
//! `batched_no_worse`: micro-batching must not lose throughput at the
//! highest offered rate.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use crate::models::zoo;
use crate::nnp::plan::CompiledNet;
use crate::quant::{self, QuantConfig};
use crate::serve::net::{NetClient, NetConfig, NetServer, Registry, PROTO_VERSION};
use crate::serve::{ServeConfig, ServeError};
use crate::tensor::{parallel, Rng};
use crate::utils::json::Json;

/// Everything one run produces: the human table and the JSON payload.
pub struct ServeBenchReport {
    pub text: String,
    pub json: Json,
}

struct RunStats {
    model: &'static str,
    batched: bool,
    offered_rps: f64,
    achieved_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    ok: usize,
    shed: usize,
    errors: usize,
}

fn quantile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// One open-loop load run: `clients` paced connections offering
/// `offered_rps` in aggregate for `duration`, each request a blocking
/// binary-protocol INFER.
fn load_run(
    addr: SocketAddr,
    model: &'static str,
    batched: bool,
    clients: usize,
    offered_rps: f64,
    duration: Duration,
) -> RunStats {
    let wall = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut cli = NetClient::connect(addr).expect("bench client connect");
                let mut rng = Rng::new(1000 + c as u64);
                let x = rng.rand(&[1, 64], -1.0, 1.0);
                let period = Duration::from_secs_f64(clients as f64 / offered_rps);
                let start = Instant::now();
                let mut next = start;
                let (mut lat_ms, mut shed, mut errors) = (Vec::new(), 0usize, 0usize);
                while start.elapsed() < duration {
                    let now = Instant::now();
                    if next > now {
                        std::thread::sleep(next - now);
                    }
                    next += period;
                    let t0 = Instant::now();
                    match cli.infer(model, std::slice::from_ref(&x)) {
                        Ok(_) => lat_ms.push(t0.elapsed().as_secs_f64() * 1e3),
                        Err(ServeError::Overloaded { .. }) => shed += 1,
                        Err(_) => errors += 1,
                    }
                }
                (lat_ms, shed, errors)
            })
        })
        .collect();
    let (mut lat_ms, mut shed, mut errors) = (Vec::new(), 0usize, 0usize);
    for h in handles {
        let (l, s, e) = h.join().expect("bench client");
        lat_ms.extend(l);
        shed += s;
        errors += e;
    }
    let elapsed = wall.elapsed().as_secs_f64().max(1e-9);
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    RunStats {
        model,
        batched,
        offered_rps,
        achieved_rps: lat_ms.len() as f64 / elapsed,
        p50_ms: quantile(&lat_ms, 0.50),
        p99_ms: quantile(&lat_ms, 0.99),
        ok: lat_ms.len(),
        shed,
        errors,
    }
}

/// Run the suite. `quick` shrinks rates/duration for CI smoke use.
pub fn run(quick: bool) -> ServeBenchReport {
    // one registry, three hostings of the zoo MLP: micro-batched f32,
    // unbatched f32, micro-batched int8 (quantized from the same net)
    let (net, params) = zoo::export_eval("mlp", 11);
    let plan = CompiledNet::compile(&net, &params).expect("mlp compile");
    let mut rng = Rng::new(7);
    let samples = crate::bench_quant::random_inputs(&net, if quick { 16 } else { 64 }, &mut rng);
    let (_, qnet) = quant::quantize_net(&net, &params, &samples, &QuantConfig::default())
        .expect("mlp quantize");

    let base = ServeConfig {
        workers: 4,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        // deep enough that the bench measures service, not shedding
        queue_cap: 4096,
    };
    let registry = std::sync::Arc::new(Registry::new(base.clone()));
    let plan: std::sync::Arc<dyn crate::nnp::plan::InferencePlan> = std::sync::Arc::new(plan);
    registry.deploy("mlp", std::sync::Arc::clone(&plan), "f32");
    registry.deploy_with(
        "mlp_unbatched",
        plan,
        "f32",
        ServeConfig { max_batch: 1, ..base.clone() },
    );
    registry.deploy("mlp_int8", std::sync::Arc::new(qnet), "int8");

    let server = NetServer::bind(
        "127.0.0.1:0",
        std::sync::Arc::clone(&registry),
        NetConfig { max_conns: 256, ..NetConfig::default() },
    )
    .expect("bench server bind");
    let addr = server.local_addr();

    let (rates, clients, duration) = if quick {
        (vec![500.0, 2000.0], 8, Duration::from_millis(300))
    } else {
        (vec![500.0, 2000.0, 8000.0], 16, Duration::from_millis(1500))
    };

    let cases: [(&'static str, bool); 3] =
        [("mlp", true), ("mlp_unbatched", false), ("mlp_int8", true)];
    let mut runs: Vec<RunStats> = Vec::new();
    for &(model, batched) in &cases {
        // warm the pools and the connection path before timing
        let mut warm = NetClient::connect(addr).expect("warmup connect");
        let wx = Rng::new(3).rand(&[1, 64], -1.0, 1.0);
        for _ in 0..8 {
            warm.infer(model, std::slice::from_ref(&wx)).expect("warmup infer");
        }
        for &rate in &rates {
            runs.push(load_run(addr, model, batched, clients, rate, duration));
        }
    }

    let top = *rates.last().expect("rates non-empty");
    let achieved_at = |name: &str| {
        runs.iter()
            .find(|r| r.model == name && r.offered_rps == top)
            .map(|r| r.achieved_rps)
            .unwrap_or(0.0)
    };
    // batching must not lose throughput where it matters (0.85 slack
    // absorbs scheduler noise on loaded CI hosts)
    let batched_no_worse = achieved_at("mlp") >= 0.85 * achieved_at("mlp_unbatched");
    let int8_served = runs.iter().any(|r| r.model == "mlp_int8" && r.ok > 0 && r.errors == 0);

    let mut text = format!(
        "serve_net bench: {} clients, {:?} per rate, NNL_THREADS={}\n\
         {:<14} {:>9} {:>10} {:>9} {:>9} {:>7} {:>6} {:>6}\n",
        clients,
        duration,
        parallel::num_threads(),
        "model",
        "offered",
        "achieved",
        "p50 ms",
        "p99 ms",
        "ok",
        "shed",
        "err",
    );
    for r in &runs {
        text.push_str(&format!(
            "{:<14} {:>9.0} {:>10.0} {:>9.3} {:>9.3} {:>7} {:>6} {:>6}\n",
            r.model, r.offered_rps, r.achieved_rps, r.p50_ms, r.p99_ms, r.ok, r.shed, r.errors,
        ));
    }
    text.push_str(&format!("batched_no_worse: {batched_no_worse}   int8_served: {int8_served}\n"));

    let json = Json::obj(vec![
        ("nnl_threads", Json::num(parallel::num_threads() as f64)),
        ("protocol_version", Json::num(PROTO_VERSION as f64)),
        ("clients", Json::num(clients as f64)),
        (
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("model", Json::str(r.model)),
                            ("batched", Json::Bool(r.batched)),
                            ("offered_rps", Json::num(r.offered_rps)),
                            ("achieved_rps", Json::num(r.achieved_rps)),
                            ("p50_ms", Json::num(r.p50_ms)),
                            ("p99_ms", Json::num(r.p99_ms)),
                            ("ok", Json::num(r.ok as f64)),
                            ("shed", Json::num(r.shed as f64)),
                            ("errors", Json::num(r.errors as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("batched_no_worse", Json::Bool(batched_no_worse)),
        ("int8_served", Json::Bool(int8_served)),
        ("robustness", robustness_totals(&registry)),
    ]);
    drop(server);
    ServeBenchReport { text, json }
}

/// Aggregate the fault-tolerance counters across every hosted model —
/// on a healthy chaos-disabled run each total is 0, which is itself
/// the number the CI smoke wants to see.
fn robustness_totals(registry: &Registry) -> Json {
    let stats = registry.stats_json();
    let keys = ["panics_caught", "worker_restarts", "deadline_expired", "retries"];
    let mut totals = [0usize; 4];
    if let Json::Obj(models) = &stats {
        for model in models.values() {
            for (i, k) in keys.iter().enumerate() {
                totals[i] += model.get(k).as_usize().unwrap_or(0);
            }
        }
    }
    Json::obj(vec![
        ("panics_caught", Json::num(totals[0] as f64)),
        ("worker_restarts", Json::num(totals[1] as f64)),
        ("deadline_expired", Json::num(totals[2] as f64)),
        ("retries", Json::num(totals[3] as f64)),
    ])
}
