//! `nnl` — the framework CLI (the paper's launcher surface): train,
//! evaluate, convert, query, search, and footprint from one binary.
//!
//! Hand-rolled arg parsing (clap is unavailable offline).

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use nnl::comm::{CommError, NetCommunicator, NetOptions};
use nnl::console::{footprint, structure_search, SearchSpace, TrialStore};
use nnl::context::Context;
use nnl::converters::{frozen, nnb, onnx_lite, query, rs_source};
use nnl::data::SyntheticImages;
use nnl::models::zoo;
use nnl::nnp::{passes, CompiledNet, InferencePlan, Nnp, OptLevel};
use nnl::quant::{self, QuantConfig};
use nnl::runtime::Manifest;
use nnl::serve::net::{NetConfig, NetServer, Registry};
use nnl::serve::{ServeConfig, Server};
use nnl::tensor::{NdArray, Rng};
use nnl::trainer::{self, DistConfig, LossScalerKind, TrainConfig, TrainReport};

const USAGE: &str = "\
nnl — Neural Network Libraries (Rust + JAX + Pallas reproduction)

USAGE:
  nnl train --model <name> [--steps N] [--lr F] [--solver sgd|momentum|adam]
            [--half] [--workers N] [--trials DIR]
  nnl train-static --artifact <name> [--steps N] [--lr F] [--half]
  nnl train-dist (--launch N | --rank R --size N --rendezvous HOST:PORT)
            [--model lenet] [--steps N] [--lr F] [--solver sgd|momentum|adam]
            [--batch B] [--seed S] [--bucket-kb KB] [--no-overlap]
            [--fp16-comm] [--deadline-ms MS]
            [--dump-dir DIR | --dump-params FILE]
            # multi-process data-parallel training over the TCP ring
            # all-reduce (bit-deterministic across world sizes; see
            # README); --launch N forks N local worker processes with
            # rank 0 in-process, --rank/--size joins a rendezvous
  nnl eval --model <name> [--steps N]
  nnl convert --in model.nnp --to onnx|nnb|frozen|rs --out FILE
  nnl quantize --in model.nnp [--out model.nnb2] [--samples N]
            [--percentile P] [--network NAME]
            # post-training int8 quantization: calibrate on N synthetic
            # samples, write an NNB2 artifact (int8 weights + scales),
            # report size vs NNB1 and fp32-vs-int8 top-1 agreement
  nnl query --in model.nnp [--target onnx|nnb|frozen|rs_source]
  nnl check --in model.nnp|model.nnb|model.nnb2 | --model NAME [--network NAME] [--json]
            # static verification: full shape inference + lints
            # (NNL-Exxx errors, NNL-Wxxx warnings) and translation
            # validation of the compiled plan at O0/O1/O2 (NNL-Pxxx);
            # exits non-zero when any error is found
  nnl optimize --in model.nnp [--network NAME] [--opt 0|1|2] [--verify]
            # inspect the compile-time graph optimizer: per-pass
            # rewrite stats, op histogram and step count before/after,
            # static-plan peak arena bytes before/after; --verify
            # re-checks every graph invariant after each pass and
            # names the pass that broke one
  nnl serve --in model.nnp|model.nnb|model.nnb2 [--workers N]
            [--max-batch B] [--max-wait-ms MS] [--queue-cap N]
            # compile once, then serve stdin requests (one line of
            # whitespace-separated floats per single-example request);
            # NNB2 artifacts serve on the int8 kernels
  nnl serve --listen HOST:PORT --models name=path[,name=path...]
            [--workers N] [--max-batch B] [--max-wait-ms MS]
            [--queue-cap N] [--no-deploy]
            # TCP serving front end: multi-model registry over the
            # length-prefixed binary protocol (JSON-per-line fallback),
            # wire DEPLOY/UNDEPLOY hot reload, /stats metrics;
            # 'quit' or EOF on stdin shuts down gracefully
  nnl bench-serve [--in model.nnp | --model NAME] [--requests N]
            [--workers N] [--max-batch B] [--max-wait-ms MS]
            # compiled-vs-interpreted and batched-vs-unbatched throughput
  nnl bench-serve --net [--quick] [--out FILE]
            # TCP load generator against the registry server: p50/p99
            # latency vs offered rps, batched vs unbatched, f32 vs
            # int8; writes BENCH_serve.json
  nnl bench-kernels [--quick] [--out FILE]
            # tiled GEMM GFLOP/s vs the naive loop, thread-scaling
            # curve, fused conv step time; writes BENCH_kernels.json
  nnl bench-quant [--quick] [--out FILE]
            # fp32 vs int8: GEMM GFLOP/s at equal thread counts, zoo
            # top-1 agreement, NNB1-vs-NNB2 artifact bytes, serve
            # throughput; writes BENCH_quant.json
  nnl bench-plan [--quick] [--out FILE]
            # graph optimizer: O0-vs-O2 step counts, peak arena bytes,
            # per-pass rewrites, serve rps; writes BENCH_plan.json
  nnl bench-comm [--quick] [--out FILE]
            # distributed training: steps/s and bytes moved at world
            # 1/2/4, overlap-on vs overlap-off, fp16 wire; writes
            # BENCH_comm.json
  nnl footprint [--model <name>]
  nnl search [--generations N] [--population N]
  nnl trials --dir DIR
  nnl models
  nnl context <spec>            # e.g. 'xla:half' — prints the parsed context
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn train_config(flags: &HashMap<String, String>) -> TrainConfig {
    let mut cfg = TrainConfig {
        steps: get(flags, "steps", 100),
        lr: get(flags, "lr", 0.05),
        weight_decay: get(flags, "weight-decay", 0.0),
        solver: flags.get("solver").cloned().unwrap_or_else(|| "momentum".into()),
        ..Default::default()
    };
    if flags.contains_key("half") {
        // Listing 2: one-line backend/precision switch
        Context::set_default(Context::get_extension_context("cpu:half").unwrap());
        cfg.loss_scale =
            Some(LossScalerKind::Dynamic { initial: 8.0, factor: 2.0, interval: 2000 });
    }
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return;
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "models" => {
            println!("available models:");
            for m in zoo::model_names() {
                let dims = zoo::input_dims(m);
                let (params, macs) = footprint(m, &dims, 10);
                println!("  {m:<22} input {dims:?}  params {params:>8}  MACs {macs:>10}");
            }
        }
        "context" => {
            let spec = args.get(1).map(String::as_str).unwrap_or("cpu:float");
            match Context::get_extension_context(spec) {
                Some(c) => println!("{c:?}"),
                None => eprintln!("unknown context '{spec}'"),
            }
        }
        "footprint" => {
            let model = flags.get("model").map(String::as_str).unwrap_or("resnet18");
            let dims = zoo::input_dims(model);
            let (params, macs) = footprint(model, &dims, 10);
            println!("{model}: {params} parameters, {macs} multiply-adds per sample");
        }
        "train" => {
            let model = flags.get("model").cloned().unwrap_or_else(|| "resnet18".into());
            let model: &'static str = Box::leak(model.into_boxed_str());
            let cfg = train_config(&flags);
            validate_train_flags(Some(model), &cfg);
            let workers: usize = get(&flags, "workers", 1);
            let data = train_data(model, 16);
            let report = if workers > 1 {
                trainer::train_distributed(model, data, &cfg, workers)
            } else {
                trainer::train_dynamic(model, &data, &cfg)
            };
            println!(
                "{model}: {} steps in {:.2}s ({:.1} steps/s), final loss {:.4}, val error {:.3}",
                report.steps,
                report.wall_secs,
                report.steps as f64 / report.wall_secs,
                report.final_loss(),
                report.val_error
            );
            if let Some(dir) = flags.get("trials") {
                let store = TrialStore::open(Path::new(dir)).expect("trial dir");
                let id = store.record(&report).expect("record trial");
                println!("recorded trial {id} in {dir}");
            }
        }
        "train-dist" => train_dist(&flags),
        "bench-comm" => {
            let report = nnl::bench_comm::run(flags.contains_key("quick"));
            print!("{}", report.text);
            let out = PathBuf::from(
                flags.get("out").cloned().unwrap_or_else(|| "BENCH_comm.json".into()),
            );
            std::fs::write(&out, report.json.to_string_pretty()).expect("writing report");
            println!("wrote {}", out.display());
        }
        "train-static" => {
            let artifact = flags
                .get("artifact")
                .cloned()
                .unwrap_or_else(|| "resnet_mini_train_f32_b16".into());
            let cfg = train_config(&flags);
            validate_train_flags(None, &cfg);
            let manifest = Manifest::load(&Manifest::default_dir())
                .expect("artifacts missing — run `make artifacts`");
            let data = SyntheticImages::imagenet_mini(16);
            let report =
                trainer::train_static(&manifest, &artifact, &data, &cfg).expect("static training");
            println!(
                "{artifact}: {} steps in {:.2}s ({:.1} steps/s), final loss {:.4}",
                report.steps,
                report.wall_secs,
                report.steps as f64 / report.wall_secs,
                report.final_loss()
            );
        }
        "eval" => {
            let model = flags.get("model").cloned().unwrap_or_else(|| "resnet18".into());
            let data = SyntheticImages::imagenet_mini(16);
            let cfg = TrainConfig { steps: get(&flags, "steps", 50), ..Default::default() };
            validate_train_flags(Some(model.as_str()), &cfg);
            let report = trainer::train_dynamic(&model, &data, &cfg);
            println!("{model}: val error {:.3}", report.val_error);
        }
        "convert" => {
            let input = PathBuf::from(flags.get("in").expect("--in model.nnp required"));
            let to = flags.get("to").expect("--to target required").clone();
            let out = PathBuf::from(flags.get("out").expect("--out FILE required"));
            let nnp = Nnp::load(&input).expect("loading NNP");
            let net = &nnp.networks[0];
            let pm = nnp.param_map();
            match to.as_str() {
                "onnx" => {
                    let m = onnx_lite::to_onnx(net, &pm).expect("onnx conversion");
                    std::fs::write(&out, onnx_lite::save_bytes(&m)).expect("write");
                }
                "nnb" => {
                    std::fs::write(&out, nnb::to_nnb(net, &nnp.parameters)).expect("write");
                }
                "frozen" => {
                    let fg = frozen::freeze(net, &pm).expect("freeze");
                    std::fs::write(&out, frozen::save_bytes(&fg)).expect("write");
                }
                "rs" | "rs_source" => {
                    let src = rs_source::generate(net, &pm).expect("source generation");
                    std::fs::write(&out, src).expect("write");
                }
                other => {
                    eprintln!("unknown target '{other}'");
                    std::process::exit(1);
                }
            }
            println!("converted {} -> {} ({to})", input.display(), out.display());
        }
        "query" => {
            let input = PathBuf::from(flags.get("in").expect("--in model.nnp required"));
            let nnp = Nnp::load(&input).expect("loading NNP");
            let net = &nnp.networks[0];
            match flags.get("target") {
                Some(t) => {
                    let target = query::Target::from_name(t).expect("unknown target");
                    let gaps = query::query_unsupported(net, target);
                    if gaps.is_empty() {
                        println!("all functions supported by {t}");
                    } else {
                        println!("unsupported by {t}: {gaps:?}");
                        std::process::exit(2);
                    }
                }
                None => print!("{}", query::support_report(net)),
            }
        }
        "serve" => {
            if let Some(addr) = flags.get("listen") {
                serve_net(addr, &flags);
                return;
            }
            let input =
                PathBuf::from(flags.get("in").expect("--in model.nnp|.nnb|.nnb2 required"));
            let (plan, _kind) = load_plan(&input, flags.get("network").map(String::as_str));
            if plan.inputs().len() != 1 {
                eprintln!(
                    "stdin serving supports single-input networks (this one declares {}); \
                     use the serve::Server API for multi-input models",
                    plan.inputs().len()
                );
                std::process::exit(1);
            }
            let cfg = serve_config(&flags);
            let mut dims = plan.inputs()[0].dims.clone();
            if !dims.is_empty() {
                dims[0] = 1;
            }
            let feat: usize = dims.iter().product();
            eprintln!(
                "serving '{}' ({} layers, input '{}' {:?}): {} workers, max batch {}, \
                 micro-batching {}",
                plan.name(),
                plan.n_steps(),
                plan.inputs()[0].name,
                dims,
                cfg.workers.max(1),
                cfg.max_batch,
                if plan.batch_invariant() { "on" } else { "off" },
            );
            eprintln!("enter {feat} whitespace-separated floats per request (EOF to stop):");
            let server = Server::start_dyn(Arc::clone(&plan), cfg);
            let stdin = std::io::stdin();
            let mut line = String::new();
            // submit ahead and print replies in input order: a window of
            // in-flight requests is what lets the worker pool and the
            // micro-batcher actually engage
            let mut pending: VecDeque<Receiver<nnl::serve::ServeResult>> = VecDeque::new();
            const WINDOW: usize = 64;
            loop {
                line.clear();
                match stdin.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
                if line.trim().is_empty() {
                    continue;
                }
                let vals: Result<Vec<f32>, _> =
                    line.split_whitespace().map(str::parse::<f32>).collect();
                let vals = match vals {
                    Ok(v) if v.len() == feat => v,
                    Ok(v) => {
                        eprintln!("expected {feat} values, got {}", v.len());
                        continue;
                    }
                    Err(e) => {
                        eprintln!("bad number: {e}");
                        continue;
                    }
                };
                match server.submit(vec![NdArray::from_vec(&dims, vals)]) {
                    Ok(rx) => pending.push_back(rx),
                    Err(e) => eprintln!("request rejected: {e}"),
                }
                while pending.len() >= WINDOW {
                    print_serve_reply(pending.pop_front().expect("non-empty window"));
                }
            }
            for rx in pending {
                print_serve_reply(rx);
            }
            eprintln!("{}", server.shutdown());
        }
        "bench-serve" => {
            if flags.contains_key("net") {
                let report = nnl::bench_serve::run(flags.contains_key("quick"));
                print!("{}", report.text);
                let out = PathBuf::from(
                    flags.get("out").cloned().unwrap_or_else(|| "BENCH_serve.json".into()),
                );
                std::fs::write(&out, report.json.to_string_pretty()).expect("writing report");
                eprintln!("wrote {}", out.display());
                return;
            }
            let (net, params) = match flags.get("in") {
                Some(p) => {
                    let nnp = Nnp::load(Path::new(p)).expect("loading NNP");
                    let net = nnp.networks.first().expect("NNP holds no networks").clone();
                    let params = nnp.param_map();
                    (net, params)
                }
                None => {
                    let model = flags.get("model").cloned().unwrap_or_else(|| "mlp".into());
                    zoo::export_eval(&model, 11)
                }
            };
            let requests: usize = get(&flags, "requests", 256);
            let cfg = serve_config(&flags);
            let report =
                nnl::serve::bench_throughput(&net, &params, requests, &cfg).expect("bench-serve");
            print!("{report}");
        }
        "bench-kernels" => {
            let report = nnl::bench_kernels::run(flags.contains_key("quick"));
            print!("{}", report.text);
            let out = PathBuf::from(
                flags.get("out").cloned().unwrap_or_else(|| "BENCH_kernels.json".into()),
            );
            nnl::bench_kernels::write_json(&out, &report.json).expect("writing bench JSON");
            println!("wrote {}", out.display());
        }
        "optimize" => {
            let input = PathBuf::from(flags.get("in").expect("--in model.nnp required"));
            let nnp = Nnp::load(&input).unwrap_or_else(|e| {
                eprintln!("loading NNP: {e}");
                std::process::exit(1);
            });
            let net = match flags.get("network").map(String::as_str) {
                Some(n) => nnp.network(n).unwrap_or_else(|| {
                    eprintln!("no network '{n}' in {}", input.display());
                    std::process::exit(1);
                }),
                None => nnp.networks.first().unwrap_or_else(|| {
                    eprintln!("NNP holds no networks");
                    std::process::exit(1);
                }),
            };
            let level = match flags.get("opt") {
                Some(v) => OptLevel::from_flag(v).unwrap_or_else(|| {
                    eprintln!("--opt expects 0, 1 or 2, got '{v}'");
                    std::process::exit(1);
                }),
                None => OptLevel::default(),
            };
            let pm = nnp.param_map();
            if flags.contains_key("verify") {
                // run the pipeline under per-pass translation
                // validation: the first invariant-breaking pass is
                // named in the error
                die(passes::optimize_verified(net, &pm, level), "per-pass verification");
                println!("per-pass verification passed at {}", level.name());
            }
            let before = die(
                CompiledNet::compile_with(net, &pm, OptLevel::O0),
                "compiling O0 plan",
            );
            let after = die(
                CompiledNet::compile_with(net, &pm, level),
                "compiling optimized plan",
            );
            println!(
                "network '{}': O0 -> {}",
                after.name(),
                level.name(),
            );
            println!(
                "  steps: {} -> {}    peak arena bytes: {} -> {}",
                before.n_steps(),
                after.n_steps(),
                before
                    .peak_arena_bytes()
                    .map_or("n/a".to_string(), |b| b.to_string()),
                after
                    .peak_arena_bytes()
                    .map_or("n/a".to_string(), |b| b.to_string()),
            );
            println!("  passes:");
            for s in after.pass_stats() {
                println!("    {:<16} {} rewrites", s.pass, s.rewrites);
            }
            let render = |h: &[(String, usize)]| {
                h.iter().map(|(n, c)| format!("{n} x{c}")).collect::<Vec<_>>().join(", ")
            };
            println!("  ops O0:           {}", render(&before.op_histogram()));
            println!("  ops {}:           {}", level.name(), render(&after.op_histogram()));
        }
        "bench-plan" => {
            let report = nnl::bench_plan::run(flags.contains_key("quick"));
            print!("{}", report.text);
            let out = PathBuf::from(
                flags.get("out").cloned().unwrap_or_else(|| "BENCH_plan.json".into()),
            );
            nnl::bench_plan::write_json(&out, &report.json).expect("writing bench JSON");
            println!("wrote {}", out.display());
        }
        "bench-quant" => {
            let report = nnl::bench_quant::run(flags.contains_key("quick"));
            print!("{}", report.text);
            let out = PathBuf::from(
                flags.get("out").cloned().unwrap_or_else(|| "BENCH_quant.json".into()),
            );
            nnl::bench_quant::write_json(&out, &report.json).expect("writing bench JSON");
            println!("wrote {}", out.display());
        }
        "quantize" => {
            let input = PathBuf::from(flags.get("in").expect("--in model.nnp required"));
            let out = flags.get("out").cloned().unwrap_or_else(|| {
                input.with_extension("nnb2").to_string_lossy().into_owned()
            });
            let nnp = Nnp::load(&input).unwrap_or_else(|e| {
                eprintln!("loading NNP: {e}");
                std::process::exit(1);
            });
            let net = match flags.get("network").map(String::as_str) {
                Some(n) => nnp.network(n).unwrap_or_else(|| {
                    eprintln!("no network '{n}' in {}", input.display());
                    std::process::exit(1);
                }),
                None => nnp.networks.first().unwrap_or_else(|| {
                    eprintln!("NNP holds no networks");
                    std::process::exit(1);
                }),
            };
            let pm = nnp.param_map();
            // a typo'd percentile must not silently fall back to
            // plain min/max calibration
            let percentile = flags.get("percentile").map(|v| {
                v.parse::<f32>().unwrap_or_else(|_| {
                    eprintln!("--percentile expects a number in (0.5, 1], got '{v}'");
                    std::process::exit(1);
                })
            });
            let cfg = QuantConfig { percentile };
            let n_samples: usize = get(&flags, "samples", 32);
            let mut rng = Rng::new(get(&flags, "seed", 19));
            let samples = nnl::bench_quant::random_inputs(net, n_samples.max(1), &mut rng);
            // optimize first (O2: BN folding, elision) so folded convs
            // quantize; the NNB2 artifact carries the optimized graph.
            // One compiled plan then drives calibration AND the fp32
            // side of the agreement report below.
            let (onet, oparams, _) = die(
                passes::optimize(net, &pm, OptLevel::default()),
                "optimizing graph",
            );
            let plan = die(CompiledNet::compile(&onet, &oparams), "compiling fp32 plan");
            let calib = die(quant::calibrate(&plan, &samples, &cfg), "calibration failed");
            let model =
                die(quant::quantize_model(&onet, &oparams, &calib), "quantization failed");
            let qnet = die(quant::QuantizedNet::compile(&model), "quantized compile failed");
            let v2 = nnb::to_nnb2(&model);
            std::fs::write(&out, &v2).expect("writing NNB2");
            // size the f32 counterpart over the same referenced params
            // NNB2 carries, so the ratio measures quantization alone
            let v1 = nnb::to_nnb(net, &quant::referenced_params(net, &pm));
            let agree = samples
                .iter()
                .filter(|s| {
                    let f = plan.execute_positional(s.as_slice()).expect("fp32 run");
                    let q = qnet.execute_positional(s.as_slice()).expect("int8 run");
                    f[0].argmax_flat() == q[0].argmax_flat()
                })
                .count();
            println!(
                "quantized '{}': {} of {} layers on int8 ({})",
                plan.name(),
                qnet.n_quantized(),
                plan.n_steps(),
                qnet.quantized_layers().join(", "),
            );
            println!(
                "wrote {out}: {} B (NNB1 equivalent {} B, {:.2}x smaller); \
                 top-1 agreement {agree}/{} on calibration samples",
                v2.len(),
                v1.len(),
                v1.len() as f64 / v2.len() as f64,
                samples.len(),
            );
        }
        "check" => {
            let json = flags.contains_key("json");
            if let Some(model) = flags.get("model") {
                // in-memory zoo check — the CI smoke path needs no
                // artifact on disk
                if !zoo::has_model(model) {
                    eprintln!(
                        "unknown model '{model}' (available: {:?})",
                        zoo::model_names()
                    );
                    std::process::exit(1);
                }
                let (net, params) = zoo::export_eval(model, 11);
                let report = nnl::nnp::verify::check_model(&net, &params);
                finish_check(vec![(model.clone(), report)], json);
            } else {
                let input = PathBuf::from(
                    flags
                        .get("in")
                        .expect("--in model.nnp|.nnb|.nnb2 or --model NAME required"),
                );
                check_cmd(&input, flags.get("network").map(String::as_str), json);
            }
        }
        "search" => {
            let data = SyntheticImages::new(10, 1, 8, 16, 1);
            let space = SearchSpace::default();
            let front = structure_search(
                &data,
                &space,
                get(&flags, "generations", 2),
                get(&flags, "population", 4),
                get(&flags, "seed", 7),
            );
            println!("Pareto front (val_error vs MACs):");
            for c in &front {
                println!(
                    "  plan {:?}: val_error {:.3}, MACs {}, params {}",
                    c.plan, c.val_error, c.macs, c.n_params
                );
            }
        }
        "trials" => {
            let dir = flags.get("dir").cloned().unwrap_or_else(|| "trials".into());
            let store = TrialStore::open(Path::new(&dir)).expect("trial dir");
            print!("{}", store.comparison_table().expect("listing"));
            if let Some(best) = store.best().expect("best") {
                println!(
                    "best: trial {} ({}, val error {:.3})",
                    best.id, best.model, best.val_error
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{USAGE}");
            std::process::exit(1);
        }
    }
}

/// Synthetic training data for `nnl train` / `nnl train-dist`, shaped
/// for the named model.
fn train_data(model: &str, batch: usize) -> SyntheticImages {
    if model == "lenet" {
        SyntheticImages::new(10, 1, 28, batch, 1)
    } else if model == "mlp" {
        SyntheticImages::new(10, 1, 8, batch, 1)
    } else {
        SyntheticImages::imagenet_mini(batch)
    }
}

/// `nnl train-dist` — multi-process data-parallel training over the
/// TCP ring all-reduce. Two entry modes: `--launch N` binds the
/// rendezvous, forks N-1 child worker processes of this same binary
/// and runs rank 0 in-process (single-command local runs — what the
/// integration test drives); `--rank R --size N --rendezvous ADDR`
/// joins an existing rendezvous (one process per rank, any hosts).
fn train_dist(flags: &HashMap<String, String>) {
    let model = flags.get("model").cloned().unwrap_or_else(|| "lenet".into());
    let cfg = TrainConfig {
        steps: get(flags, "steps", 20),
        lr: get(flags, "lr", 0.05),
        weight_decay: get(flags, "weight-decay", 0.0),
        solver: flags.get("solver").cloned().unwrap_or_else(|| "momentum".into()),
        val_batches: get(flags, "val-batches", 1),
        seed: get(flags, "seed", 313),
        ..Default::default()
    };
    validate_train_flags(Some(model.as_str()), &cfg);
    let dist = DistConfig {
        bucket_bytes: get(flags, "bucket-kb", 4096usize) * 1024,
        overlap: !flags.contains_key("no-overlap"),
    };
    let batch: usize = get(flags, "batch", 16);
    let opts = NetOptions {
        step_deadline: Duration::from_millis(get(flags, "deadline-ms", 30_000u64)),
        fp16_wire: flags.contains_key("fp16-comm"),
        ..NetOptions::default()
    };
    let data = train_data(&model, batch);

    if flags.contains_key("launch") {
        let size: usize = get(flags, "launch", 0);
        if size == 0 {
            eprintln!("--launch expects a worker count >= 1");
            std::process::exit(1);
        }
        // bind before forking so every child finds a live rendezvous
        let bind_addr =
            flags.get("rendezvous").map(String::as_str).unwrap_or("127.0.0.1:0");
        let listener = NetCommunicator::rendezvous_bind(bind_addr).unwrap_or_else(|e| {
            eprintln!("binding rendezvous {bind_addr}: {e}");
            std::process::exit(1);
        });
        let addr = listener.local_addr().expect("listener addr").to_string();
        let exe = std::env::current_exe().expect("current exe");
        let mut children = Vec::new();
        for rank in 1..size {
            let mut c = std::process::Command::new(&exe);
            c.arg("train-dist")
                .args(["--rank", &rank.to_string()])
                .args(["--size", &size.to_string()])
                .args(["--rendezvous", &addr])
                .args(["--model", &model])
                .args(["--steps", &cfg.steps.to_string()])
                .args(["--lr", &cfg.lr.to_string()])
                .args(["--solver", &cfg.solver])
                .args(["--batch", &batch.to_string()])
                .args(["--seed", &cfg.seed.to_string()])
                .args(["--bucket-kb", &(dist.bucket_bytes / 1024).to_string()])
                .args(["--deadline-ms", &opts.step_deadline.as_millis().to_string()]);
            if !dist.overlap {
                c.arg("--no-overlap");
            }
            if opts.fp16_wire {
                c.arg("--fp16-comm");
            }
            if let Some(dir) = flags.get("dump-dir") {
                c.args(["--dump-dir", dir]);
            }
            let child = c.spawn().unwrap_or_else(|e| {
                eprintln!("spawning rank {rank}: {e}");
                std::process::exit(1);
            });
            children.push((rank, child));
        }
        let result = NetCommunicator::connect_with_listener(listener, size, opts)
            .and_then(|comm| trainer::train_worker(&model, &data, &cfg, &dist, comm, "cpu:tcp"));
        let mut child_failed = false;
        for (rank, mut child) in children {
            match child.wait() {
                Ok(st) if st.success() => {}
                Ok(st) => {
                    eprintln!("rank {rank} exited with {st}");
                    child_failed = true;
                }
                Err(e) => {
                    eprintln!("waiting on rank {rank}: {e}");
                    child_failed = true;
                }
            }
        }
        finish_dist(result, 0, flags, child_failed);
    } else {
        let rank: usize = get(flags, "rank", 0);
        let size: usize = get(flags, "size", 1);
        let rendezvous =
            flags.get("rendezvous").cloned().unwrap_or_else(|| "127.0.0.1:29500".into());
        let result = NetCommunicator::connect(rank, size, &rendezvous, opts)
            .and_then(|comm| trainer::train_worker(&model, &data, &cfg, &dist, comm, "cpu:tcp"));
        finish_dist(result, rank, flags, false);
    }
}

/// Finish one `train-dist` rank: dump parameters if asked, print the
/// rank-0 summary, exit non-zero on any comm error or failed child.
fn finish_dist(
    result: Result<TrainReport, CommError>,
    rank: usize,
    flags: &HashMap<String, String>,
    child_failed: bool,
) {
    let report = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("rank {rank}: {e}");
            std::process::exit(1);
        }
    };
    let dump = flags
        .get("dump-params")
        .cloned()
        .or_else(|| flags.get("dump-dir").map(|d| format!("{d}/params_rank{rank}.bin")));
    if let Some(path) = dump {
        trainer::dump_registry_params(&path).unwrap_or_else(|e| {
            eprintln!("rank {rank}: writing {path}: {e}");
            std::process::exit(1);
        });
    }
    if rank == 0 {
        println!(
            "{}: {} steps in {:.2}s ({:.1} steps/s), final loss {:.4}, val error {:.3}",
            report.model,
            report.steps,
            report.wall_secs,
            report.steps as f64 / report.wall_secs,
            report.final_loss(),
            report.val_error
        );
    }
    if child_failed {
        std::process::exit(1);
    }
}

/// Unwrap a pipeline step or exit with a clean one-line message.
fn die<T>(r: Result<T, String>, what: &str) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("{what}: {e}");
        std::process::exit(1);
    })
}

/// `nnl check`: static verification of an artifact. NNB/NNB2 images
/// (sniffed by magic) run [`nnl::nnp::verify::check_artifact`]; `.nnp`
/// archives verify every network (or just `--network`). Exits 1 when
/// any error-severity diagnostic is found; warnings alone exit 0.
fn check_cmd(path: &Path, network: Option<&str>, json: bool) {
    use nnl::nnp::verify;
    use std::io::Read;

    let mut magic = [0u8; 4];
    let is_nnb = std::fs::File::open(path).and_then(|mut f| f.read_exact(&mut magic)).is_ok()
        && (&magic == b"NNB1" || &magic == b"NNB2");

    let mut reports: Vec<(String, verify::Report)> = Vec::new();
    if is_nnb {
        let bytes = std::fs::read(path).expect("reading model file");
        let report = die(verify::check_artifact(&bytes), "decoding NNB image");
        reports.push((path.display().to_string(), report));
    } else {
        let nnp = die(Nnp::load(path), "loading NNP");
        let pm = nnp.param_map();
        let nets: Vec<&nnl::nnp::NetworkDef> = match network {
            Some(n) => vec![nnp.network(n).unwrap_or_else(|| {
                eprintln!("no network '{n}' in {}", path.display());
                std::process::exit(1);
            })],
            None => nnp.networks.iter().collect(),
        };
        if nets.is_empty() {
            eprintln!("NNP holds no networks");
            std::process::exit(1);
        }
        for net in nets {
            reports.push((net.name.clone(), verify::check_model(net, &pm)));
        }
    }

    finish_check(reports, json);
}

/// Print `nnl check` reports (human or `--json`) and exit 1 when any
/// error-severity diagnostic is present; warnings alone exit 0.
fn finish_check(reports: Vec<(String, nnl::nnp::verify::Report)>, json: bool) {
    use nnl::utils::json::Json;
    let any_errors = reports.iter().any(|(_, r)| r.has_errors());
    if json {
        let obj =
            Json::obj(reports.iter().map(|(n, r)| (n.as_str(), r.to_json())).collect());
        println!("{}", obj.to_string_pretty());
    } else {
        for (name, r) in &reports {
            if r.is_clean() {
                println!("'{name}': clean (0 errors, 0 warnings)");
            } else {
                println!("'{name}':");
                println!("{}", r.render_human());
            }
        }
    }
    if any_errors {
        std::process::exit(1);
    }
}

/// Exit with a clean message on an unknown model or solver name —
/// untrusted CLI config must never reach the panicking internals.
fn validate_train_flags(model: Option<&str>, cfg: &TrainConfig) {
    if let Some(m) = model {
        if !zoo::has_model(m) {
            eprintln!("unknown model '{m}' (available: {:?})", zoo::model_names());
            std::process::exit(1);
        }
    }
    if let Err(e) = trainer::try_make_solver(cfg) {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

/// Load a servable plan from an `.nnp` archive or a raw NNB/NNB2
/// image (sniffed by magic, not extension): NNB2 artifacts come back
/// as int8 [`nnl::quant::QuantizedNet`] plans, everything else as f32
/// [`CompiledNet`] plans.
fn load_plan(path: &Path, network: Option<&str>) -> (Arc<dyn InferencePlan>, &'static str) {
    use std::io::Read;
    let mut magic = [0u8; 4];
    let is_nnb = std::fs::File::open(path).and_then(|mut f| f.read_exact(&mut magic)).is_ok()
        && (&magic == b"NNB1" || &magic == b"NNB2");
    if is_nnb {
        let bytes = std::fs::read(path).expect("reading model file");
        match nnb::NnbEngine::load(&bytes) {
            Ok(nnb::NnbEngine::F32(p)) => (Arc::new(p), "f32"),
            Ok(nnb::NnbEngine::Int8(q)) => (Arc::new(q), "int8"),
            Err(e) => {
                eprintln!("loading NNB image: {e}");
                std::process::exit(1);
            }
        }
    } else {
        let nnp = Nnp::load(path).expect("loading NNP");
        (Arc::new(nnp.compile(network).expect("compiling plan")), "f32")
    }
}

/// `nnl serve --listen ADDR --models name=path,...` — the TCP serving
/// front end: deploy every named artifact into one registry, listen,
/// and shut down gracefully on stdin EOF / `quit` (no request admitted
/// before shutdown is dropped).
fn serve_net(addr: &str, flags: &HashMap<String, String>) {
    let registry = Arc::new(Registry::new(serve_config(flags)));
    let specs = flags.get("models").cloned().unwrap_or_default();
    for spec in specs.split(',').filter(|s| !s.trim().is_empty()) {
        let Some((name, path)) = spec.split_once('=') else {
            eprintln!("--models expects name=path[,name=path...], got '{spec}'");
            std::process::exit(1);
        };
        let (plan, kind) = load_plan(Path::new(path.trim()), None);
        registry.deploy(name.trim(), plan, kind);
        eprintln!("deployed '{}' ({kind}) from {}", name.trim(), path.trim());
    }
    if registry.is_empty() {
        eprintln!("no models deployed (pass --models name=path,...);");
        eprintln!("serving an empty registry — clients can still DEPLOY over the wire");
    }
    let net_cfg = NetConfig {
        allow_deploy: !flags.contains_key("no-deploy"),
        ..NetConfig::default()
    };
    let server = match NetServer::bind(addr, Arc::clone(&registry), net_cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("binding {addr}: {e}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "listening on {} ({} models); 'quit' or EOF shuts down",
        server.local_addr(),
        registry.len()
    );
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        match stdin.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) if line.trim() == "quit" => break,
            Ok(_) if line.trim() == "stats" => {
                println!("{}", registry.stats_json().to_string_pretty());
            }
            Ok(_) => {}
        }
    }
    eprintln!("draining connections...");
    server.shutdown();
    eprintln!("{}", registry.stats_json().to_string_pretty());
}

fn serve_config(flags: &HashMap<String, String>) -> ServeConfig {
    ServeConfig {
        workers: get(flags, "workers", 2),
        max_batch: get(flags, "max-batch", 8),
        max_wait: Duration::from_millis(get(flags, "max-wait-ms", 2)),
        queue_cap: get(flags, "queue-cap", 0),
    }
}

/// One output tensor as a line of fixed-precision floats.
fn render_row(o: &NdArray) -> String {
    o.data().iter().map(|v| format!("{v:.6}")).collect::<Vec<_>>().join(" ")
}

/// Print one serving reply (outputs joined with " | ") in input order.
fn print_serve_reply(rx: Receiver<nnl::serve::ServeResult>) {
    match rx.recv() {
        Ok(Ok(outs)) => {
            let rendered: Vec<String> = outs.iter().map(render_row).collect();
            println!("{}", rendered.join(" | "));
        }
        Ok(Err(e)) => eprintln!("request failed: {e}"),
        Err(_) => eprintln!("server shut down before replying"),
    }
}
