//! The production serving front end: a TCP protocol over a
//! multi-model registry with atomic hot reload and live metrics —
//! the subsystem that makes the compiled-plan / int8 / pass-pipeline
//! stack reachable over a socket (paper §1: "from research to
//! production servers"; ROADMAP: the millions-of-users story made
//! measurable).
//!
//! ## Wire protocol
//!
//! Length-prefixed binary frames, version-tagged:
//!
//! ```text
//! frame    := u32_le payload_len, payload          (len <= 64 MiB)
//! request  := version:u8 verb:u8 body
//! response := version:u8 status:u8 body            (status 0 = OK,
//!                                                   else ServeError::code)
//! string   := u32_le len, utf8 bytes
//! tensor   := ndim:u8, ndim x u32_le dims, f32_le data
//! verbs    := INFER(1)  model:string n:u8 n x tensor
//!             STATS(2)                      -> string (JSON per-model metrics)
//!             LIST(3)                       -> string (JSON model list)
//!             DEPLOY(4) model:string u32_le len, NNB1/NNB2 image bytes
//!                                           -> string (JSON {version, kind})
//!             UNDEPLOY(5) model:string
//!             PING(6)
//!             HEALTH(7)                     -> string (JSON readiness)
//! error    := status:u8 != 0, message:string
//! ```
//!
//! A connection whose first byte is `{` speaks the **line-oriented
//! JSON fallback** instead (one request object per line, one reply
//! object per line) — the same verbs, telnet-able, used by tests and
//! debugging: `{"verb":"infer","model":"m","inputs":[{"dims":[1,2],
//! "data":[0.5,1.0]}]}`.
//!
//! ## Registry and hot reload
//!
//! [`Registry`] hosts many models concurrently, each entry a
//! [`crate::serve::Server`] (bounded queue + worker pool) behind an
//! `Arc` that [`Registry::deploy`] **atomically swaps**: submitting
//! clones the current `Arc` (that clone *is* the linearization
//! point), so in-flight requests finish on the plan they were admitted
//! to while new requests land on the new one; the old pool drains its
//! backlog and joins when its last in-flight holder releases it —
//! zero requests fail across a swap. Per-model [`ModelMetrics`]
//! survive swaps, so `/stats` describes the model as clients saw it.
//!
//! Admission control is per model: the bounded queue capacity defaults
//! to a limit derived from the plan's static-memory-plan
//! `peak_arena_bytes` ([`crate::serve::derive_queue_cap`]), and a full
//! queue replies [`ServeError::Overloaded`] — typed, immediate, never
//! a timeout.
//!
//! ## Fault tolerance
//!
//! Both protocols cap one message ([`MAX_FRAME`] for binary frames,
//! [`NetConfig::max_line`] for JSON lines) and answer the violation
//! with a typed error before closing — framing is unrecoverable, so
//! the connection never limps on desynchronized. Connection handlers
//! are panic-isolated (a handler that dies takes only its own
//! connection, and the live-connection gauge is restored by a drop
//! guard), the `HEALTH` verb reports per-model readiness for load
//! balancers, and [`NetClient::infer_with_retry`] reconnects and
//! retries transient transport faults with jittered backoff. The
//! [`crate::faults`] chaos hooks (`net.read`, `net.write`, `decode`)
//! inject resets, truncated replies, and corrupt artifacts on a
//! deterministic schedule under `--features chaos`.
//!
//! CLI: `nnl serve --listen ADDR --models name=path,...`; load
//! numbers: `nnl bench-serve --net` / `benches/serve_net.rs`
//! (`BENCH_serve.json`).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::faults;
use crate::monitor::metrics::ModelMetrics;
use crate::nnp::plan::InferencePlan;
use crate::serve::{RetryPolicy, ServeConfig, ServeError, ServeResult, Server};
use crate::tensor::NdArray;
use crate::utils::json::Json;

/// Protocol version carried in every frame.
pub const PROTO_VERSION: u8 = 1;
/// Hard cap on one frame's payload (requests and replies).
pub const MAX_FRAME: usize = 64 << 20;
/// Hard cap on one decoded tensor's rank.
pub const MAX_NDIM: usize = 8;

/// Request verbs.
pub mod verb {
    pub const INFER: u8 = 1;
    pub const STATS: u8 = 2;
    pub const LIST: u8 = 3;
    pub const DEPLOY: u8 = 4;
    pub const UNDEPLOY: u8 = 5;
    pub const PING: u8 = 6;
    pub const HEALTH: u8 = 7;
}

// ---------------------------------------------------------------- registry

/// One plan incarnation hosted under a model name: the worker pool
/// plus the version stamp hot reload bumps.
pub struct Hosted {
    version: u64,
    kind: &'static str,
    server: Server,
}

impl Hosted {
    pub fn version(&self) -> u64 {
        self.version
    }

    /// `"f32"` or `"int8"`.
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    pub fn server(&self) -> &Server {
        &self.server
    }
}

struct ModelSlot {
    name: String,
    metrics: Arc<ModelMetrics>,
    host: RwLock<Arc<Hosted>>,
}

/// An admitted request plus the plan incarnation serving it — holding
/// the `Arc<Hosted>` until the reply arrives is what lets a hot swap
/// proceed while in-flight requests still finish on the old plan.
pub struct Pending {
    rx: Receiver<ServeResult>,
    _host: Arc<Hosted>,
}

impl Pending {
    /// Block for the reply.
    pub fn wait(self) -> ServeResult {
        self.rx.recv().map_err(|_| ServeError::ShuttingDown)?
    }
}

/// Static description of one registry entry (the `LIST` verb's rows).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub version: u64,
    pub kind: String,
    /// Declared inputs as `(name, dims)`.
    pub inputs: Vec<(String, Vec<usize>)>,
    pub queue_cap: usize,
    pub batched: bool,
}

/// The multi-model registry: concurrent lookup, atomic hot swap,
/// per-model metrics and admission control. Cheap to share
/// (`Arc<Registry>`); every method is `&self`.
pub struct Registry {
    models: RwLock<HashMap<String, Arc<ModelSlot>>>,
    default_cfg: ServeConfig,
}

impl Registry {
    /// `default_cfg` applies to every deploy that doesn't bring its
    /// own config (`queue_cap: 0` keeps the per-plan derived cap).
    pub fn new(default_cfg: ServeConfig) -> Registry {
        Registry { models: RwLock::new(HashMap::new()), default_cfg }
    }

    /// Add or hot-swap `name`. Returns the new version (1 for a fresh
    /// entry). The swap is atomic: requests admitted before it finish
    /// on the old plan (whose pool drains and joins once its last
    /// in-flight holder lets go), requests after it land on the new
    /// plan, and nobody observes a gap.
    pub fn deploy(&self, name: &str, plan: Arc<dyn InferencePlan>, kind: &'static str) -> u64 {
        self.deploy_with(name, plan, kind, self.default_cfg.clone())
    }

    /// [`Registry::deploy`] with a per-model [`ServeConfig`].
    pub fn deploy_with(
        &self,
        name: &str,
        plan: Arc<dyn InferencePlan>,
        kind: &'static str,
        cfg: ServeConfig,
    ) -> u64 {
        // the old incarnation must drop *outside* the locks: its Drop
        // drains a worker pool, and that must never stall submitters
        let mut retired: Option<Arc<Hosted>> = None;
        let version;
        {
            let mut map = self.models.write().expect("registry lock");
            match map.get(name) {
                Some(slot) => {
                    version = slot.host.read().expect("slot lock").version + 1;
                    let server = Server::start_shared(plan, cfg, Arc::clone(&slot.metrics));
                    let next = Arc::new(Hosted { version, kind, server });
                    retired = Some(std::mem::replace(
                        &mut *slot.host.write().expect("slot lock"),
                        next,
                    ));
                    slot.metrics.swaps.fetch_add(1, Ordering::Relaxed);
                }
                None => {
                    version = 1;
                    let metrics = Arc::new(ModelMetrics::default());
                    let server = Server::start_shared(plan, cfg, Arc::clone(&metrics));
                    map.insert(
                        name.to_string(),
                        Arc::new(ModelSlot {
                            name: name.to_string(),
                            metrics,
                            host: RwLock::new(Arc::new(Hosted { version, kind, server })),
                        }),
                    );
                }
            }
        }
        drop(retired);
        version
    }

    /// Deploy from raw artifact bytes (magic-sniffed NNB1 → f32 plan,
    /// NNB2 → int8 plan) — the `DEPLOY` verb's backend. NNP archives
    /// are path-shaped (zip), so they deploy via the CLI, not the wire.
    ///
    /// Every artifact runs the full static verifier
    /// ([`crate::nnp::verify`]) before the hot-swap: a graph whose
    /// shapes do not close or whose compiled plan fails translation
    /// validation is rejected as [`ServeError::InvalidRequest`] (the
    /// first stable `NNL-*` code in the message) and live traffic
    /// never sees it.
    pub fn deploy_artifact(
        &self,
        name: &str,
        bytes: &[u8],
    ) -> Result<(u64, &'static str), ServeError> {
        // Chaos hook: a `decode:corrupt` rule bit-flips a copy of the
        // image so the static verifier (not live traffic) has to catch
        // it; `ioerr` models a decode that fails outright.
        let chaos_copy: Option<Vec<u8>> = match faults::fired(faults::Point::ArtifactDecode) {
            Some(faults::Fired::Corrupt(seed)) => {
                let mut c = bytes.to_vec();
                faults::flip_bytes(seed, &mut c);
                Some(c)
            }
            Some(faults::Fired::Delay(d)) => {
                std::thread::sleep(d);
                None
            }
            Some(faults::Fired::Panic) => panic!("chaos: injected panic at artifact decode"),
            Some(faults::Fired::IoErr) => {
                return Err(ServeError::InvalidRequest(
                    "chaos: injected artifact decode failure".to_string(),
                ));
            }
            None => None,
        };
        let bytes: &[u8] = chaos_copy.as_deref().unwrap_or(bytes);
        if bytes.len() < 4 || (&bytes[..4] != b"NNB1" && &bytes[..4] != b"NNB2") {
            return Err(ServeError::Protocol(
                "DEPLOY expects an NNB1/NNB2 image (deploy .nnp archives via the CLI)"
                    .to_string(),
            ));
        }
        // Static verification gate. `check_artifact` re-decodes the
        // image; that double decode is fine on the deploy path (cold,
        // human-paced) and keeps the verifier independent of the
        // engine it guards.
        let report = crate::nnp::verify::check_artifact(bytes)
            .map_err(ServeError::InvalidRequest)?;
        if report.has_errors() {
            return Err(ServeError::InvalidRequest(format!(
                "artifact failed static verification:\n{}",
                report.render_human()
            )));
        }
        let (plan, kind): (Arc<dyn InferencePlan>, &'static str) =
            match crate::converters::nnb::NnbEngine::load(bytes)
                .map_err(ServeError::InvalidRequest)?
            {
                crate::converters::nnb::NnbEngine::F32(p) => (Arc::new(p), "f32"),
                crate::converters::nnb::NnbEngine::Int8(q) => (Arc::new(q), "int8"),
            };
        Ok((self.deploy(name, plan, kind), kind))
    }

    /// Drop a model. In-flight requests still finish (the slot dies
    /// only when its last holder releases it); later lookups get
    /// [`ServeError::NoSuchModel`].
    pub fn remove(&self, name: &str) -> bool {
        let slot = self.models.write().expect("registry lock").remove(name);
        slot.is_some()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.models.read().expect("registry lock").contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.models.read().expect("registry lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a request to `name`'s current plan incarnation. The
    /// returned [`Pending`] pins that incarnation until the reply.
    pub fn submit(&self, name: &str, inputs: Vec<NdArray>) -> Result<Pending, ServeError> {
        let slot = self
            .models
            .read()
            .expect("registry lock")
            .get(name)
            .cloned()
            .ok_or_else(|| ServeError::NoSuchModel(name.to_string()))?;
        let host = Arc::clone(&slot.host.read().expect("slot lock")); // <- the swap point
        let rx = host.server.submit(inputs)?;
        Ok(Pending { rx, _host: host })
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, name: &str, inputs: Vec<NdArray>) -> ServeResult {
        self.submit(name, inputs)?.wait()
    }

    /// The current version under `name`, if hosted.
    pub fn version(&self, name: &str) -> Option<u64> {
        let slot = self.models.read().expect("registry lock").get(name).cloned()?;
        let v = slot.host.read().expect("slot lock").version;
        Some(v)
    }

    /// Static rows for the `LIST` verb, sorted by name.
    pub fn list(&self) -> Vec<ModelInfo> {
        let slots: Vec<Arc<ModelSlot>> =
            self.models.read().expect("registry lock").values().cloned().collect();
        let mut rows: Vec<ModelInfo> = slots
            .iter()
            .map(|slot| {
                let host = Arc::clone(&slot.host.read().expect("slot lock"));
                ModelInfo {
                    name: slot.name.clone(),
                    version: host.version,
                    kind: host.kind.to_string(),
                    inputs: host
                        .server
                        .plan()
                        .inputs()
                        .iter()
                        .map(|t| (t.name.clone(), t.dims.clone()))
                        .collect(),
                    queue_cap: host.server.queue_cap(),
                    batched: host.server.batched(),
                }
            })
            .collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    /// The `/stats` payload: per-model live metrics (latency
    /// histogram percentiles, throughput, queue depth, batch-size
    /// distribution, shed counts) plus version/kind/limits.
    pub fn stats_json(&self) -> Json {
        let mut out = std::collections::BTreeMap::new();
        for info in self.list() {
            let slot = self.models.read().expect("registry lock").get(&info.name).cloned();
            let Some(slot) = slot else { continue };
            let mut obj = match slot.metrics.snapshot().to_json() {
                Json::Obj(m) => m,
                _ => unreachable!("snapshot renders an object"),
            };
            obj.insert("version".to_string(), Json::num(info.version as f64));
            obj.insert("kind".to_string(), Json::str(info.kind.clone()));
            obj.insert("queue_cap".to_string(), Json::num(info.queue_cap as f64));
            obj.insert("batched".to_string(), Json::Bool(info.batched));
            out.insert(info.name, Json::Obj(obj));
        }
        Json::Obj(out)
    }

    /// The `HEALTH` verb's payload: per-model readiness plus the
    /// supervision counters. A model is **ready** when at least one
    /// worker thread is alive and its queue sits below the admission
    /// cap; the top-level `ready` is the conjunction over all models
    /// (an empty registry is not ready — nothing can serve).
    pub fn health_json(&self) -> Json {
        let slots: Vec<Arc<ModelSlot>> =
            self.models.read().expect("registry lock").values().cloned().collect();
        let mut models = std::collections::BTreeMap::new();
        let mut all_ready = !slots.is_empty();
        for slot in &slots {
            let host = Arc::clone(&slot.host.read().expect("slot lock"));
            let alive = host.server.alive_workers();
            let depth = slot.metrics.queue_depth.load(Ordering::Relaxed) as usize;
            let cap = host.server.queue_cap();
            let ready = alive > 0 && depth < cap;
            all_ready &= ready;
            models.insert(
                slot.name.clone(),
                Json::obj(vec![
                    ("ready", Json::Bool(ready)),
                    ("version", Json::num(host.version as f64)),
                    ("kind", Json::str(host.kind)),
                    ("workers_alive", Json::num(alive as f64)),
                    (
                        "worker_restarts",
                        Json::num(slot.metrics.worker_restarts.load(Ordering::Relaxed) as f64),
                    ),
                    ("queue_depth", Json::num(depth as f64)),
                    ("queue_cap", Json::num(cap as f64)),
                ]),
            );
        }
        Json::obj(vec![
            ("ready", Json::Bool(all_ready)),
            ("pool_restarts", Json::num(crate::tensor::parallel::worker_restarts() as f64)),
            ("models", Json::Obj(models)),
        ])
    }
}

// ------------------------------------------------------------ wire encode

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, a: &NdArray) {
    buf.push(a.dims().len() as u8);
    for &d in a.dims() {
        put_u32(buf, d as u32);
    }
    for v in a.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// The bounds-checked reader's shared truncation error.
fn truncated() -> ServeError {
    ServeError::Protocol("truncated frame".to_string())
}

/// Bounds-checked reader over one untrusted payload — every length
/// and every dimension product is validated before allocation, in the
/// same spirit as the hardened NNP/NNB decoders.
struct Wire<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Wire<'a> {
    fn new(b: &'a [u8]) -> Wire<'a> {
        Wire { b, pos: 0 }
    }

    fn u8(&mut self) -> Result<u8, ServeError> {
        let v = *self.b.get(self.pos).ok_or_else(truncated)?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, ServeError> {
        let end = self.pos.checked_add(4).filter(|&e| e <= self.b.len()).ok_or_else(truncated)?;
        let v = u32::from_le_bytes(self.b[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], ServeError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len()).ok_or_else(truncated)?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn str_(&mut self) -> Result<String, ServeError> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| ServeError::Protocol("string is not utf-8".to_string()))
    }

    fn tensor(&mut self) -> Result<NdArray, ServeError> {
        let ndim = self.u8()? as usize;
        if ndim == 0 || ndim > MAX_NDIM {
            return Err(ServeError::Protocol(format!(
                "tensor rank {ndim} outside 1..={MAX_NDIM}"
            )));
        }
        let mut dims = Vec::with_capacity(ndim);
        let mut elems: usize = 1;
        for _ in 0..ndim {
            let d = self.u32()? as usize;
            elems = elems
                .checked_mul(d)
                .filter(|&e| e.checked_mul(4).is_some_and(|b| b <= MAX_FRAME))
                .ok_or_else(|| {
                    ServeError::Protocol("tensor size overflows the frame cap".to_string())
                })?;
            dims.push(d);
        }
        let raw = self.bytes(elems * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(NdArray::from_vec(&dims, data))
    }
}

/// Write one `[u32 len][payload]` frame.
fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    let mut msg = Vec::with_capacity(4 + payload.len());
    put_u32(&mut msg, payload.len() as u32);
    msg.extend_from_slice(payload);
    stream.write_all(&msg)
}

fn ok_header() -> Vec<u8> {
    vec![PROTO_VERSION, 0]
}

fn err_payload(e: &ServeError) -> Vec<u8> {
    let mut p = vec![PROTO_VERSION, e.code()];
    put_str(&mut p, &e.to_string());
    p
}

// ---------------------------------------------------------- request handling

/// Decode and serve one binary request payload; always returns a
/// response payload (errors become typed error frames).
fn handle_binary(registry: &Registry, payload: &[u8], allow_deploy: bool) -> Vec<u8> {
    match handle_binary_inner(registry, payload, allow_deploy) {
        Ok(resp) => resp,
        Err(e) => err_payload(&e),
    }
}

fn handle_binary_inner(
    registry: &Registry,
    payload: &[u8],
    allow_deploy: bool,
) -> Result<Vec<u8>, ServeError> {
    let mut w = Wire::new(payload);
    let version = w.u8()?;
    if version != PROTO_VERSION {
        return Err(ServeError::Protocol(format!(
            "unsupported protocol version {version} (this server speaks {PROTO_VERSION})"
        )));
    }
    let v = w.u8()?;
    match v {
        verb::INFER => {
            let model = w.str_()?;
            let n = w.u8()? as usize;
            let mut inputs = Vec::with_capacity(n);
            for _ in 0..n {
                inputs.push(w.tensor()?);
            }
            let outs = registry.infer(&model, inputs)?;
            let mut resp = ok_header();
            resp.push(outs.len() as u8);
            for o in &outs {
                put_tensor(&mut resp, o);
            }
            Ok(resp)
        }
        verb::STATS => {
            let mut resp = ok_header();
            put_str(&mut resp, &registry.stats_json().to_string());
            Ok(resp)
        }
        verb::LIST => {
            let rows: Vec<Json> = registry
                .list()
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("name", Json::str(m.name.clone())),
                        ("version", Json::num(m.version as f64)),
                        ("kind", Json::str(m.kind.clone())),
                        (
                            "inputs",
                            Json::Arr(
                                m.inputs
                                    .iter()
                                    .map(|(n, d)| {
                                        Json::obj(vec![
                                            ("name", Json::str(n.clone())),
                                            ("dims", Json::arr_of_usize(d)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("queue_cap", Json::num(m.queue_cap as f64)),
                        ("batched", Json::Bool(m.batched)),
                    ])
                })
                .collect();
            let mut resp = ok_header();
            put_str(&mut resp, &Json::Arr(rows).to_string());
            Ok(resp)
        }
        verb::DEPLOY => {
            if !allow_deploy {
                return Err(ServeError::InvalidRequest(
                    "wire deploys are disabled on this server".to_string(),
                ));
            }
            let model = w.str_()?;
            let n = w.u32()? as usize;
            if n > MAX_FRAME {
                return Err(ServeError::Protocol("artifact exceeds frame cap".to_string()));
            }
            let image = w.bytes(n)?;
            let (version, kind) = registry.deploy_artifact(&model, image)?;
            let reply = Json::obj(vec![
                ("model", Json::str(model)),
                ("version", Json::num(version as f64)),
                ("kind", Json::str(kind)),
            ]);
            let mut resp = ok_header();
            put_str(&mut resp, &reply.to_string());
            Ok(resp)
        }
        verb::UNDEPLOY => {
            if !allow_deploy {
                return Err(ServeError::InvalidRequest(
                    "wire deploys are disabled on this server".to_string(),
                ));
            }
            let model = w.str_()?;
            if registry.remove(&model) {
                Ok(ok_header())
            } else {
                Err(ServeError::NoSuchModel(model))
            }
        }
        verb::PING => Ok(ok_header()),
        verb::HEALTH => {
            let mut resp = ok_header();
            put_str(&mut resp, &registry.health_json().to_string());
            Ok(resp)
        }
        other => Err(ServeError::Protocol(format!("unknown verb {other}"))),
    }
}

fn json_tensor(j: &Json) -> Result<NdArray, ServeError> {
    let dims = j
        .get("dims")
        .usize_arr()
        .filter(|d| !d.is_empty() && d.len() <= MAX_NDIM)
        .ok_or_else(|| ServeError::Protocol("tensor needs a 'dims' array".to_string()))?;
    let data = j
        .get("data")
        .as_arr()
        .ok_or_else(|| ServeError::Protocol("tensor needs a 'data' array".to_string()))?;
    let elems = dims
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .filter(|&e| e.checked_mul(4).is_some_and(|b| b <= MAX_FRAME))
        .ok_or_else(|| ServeError::Protocol("tensor size overflows".to_string()))?;
    if data.len() != elems {
        return Err(ServeError::Protocol(format!(
            "dims {dims:?} imply {elems} values, 'data' has {}",
            data.len()
        )));
    }
    let vals: Option<Vec<f32>> = data.iter().map(|v| v.as_f64().map(|f| f as f32)).collect();
    let vals = vals.ok_or_else(|| ServeError::Protocol("'data' must be numbers".to_string()))?;
    Ok(NdArray::from_vec(&dims, vals))
}

fn tensor_json(a: &NdArray) -> Json {
    Json::obj(vec![
        ("dims", Json::arr_of_usize(a.dims())),
        ("data", Json::Arr(a.data().iter().map(|&v| Json::num(v as f64)).collect())),
    ])
}

fn json_err(e: &ServeError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(e.kind())),
        ("code", Json::num(e.code() as f64)),
        ("message", Json::str(e.to_string())),
    ])
}

/// Serve one line of the JSON fallback protocol; always returns a
/// reply object (never panics on hostile input).
pub fn handle_json_line(registry: &Registry, line: &str) -> Json {
    match handle_json_inner(registry, line) {
        Ok(j) => j,
        Err(e) => json_err(&e),
    }
}

fn handle_json_inner(registry: &Registry, line: &str) -> Result<Json, ServeError> {
    let req = Json::parse(line).map_err(ServeError::Protocol)?;
    let verb = req
        .get("verb")
        .as_str()
        .ok_or_else(|| ServeError::Protocol("request needs a 'verb'".to_string()))?;
    match verb {
        "infer" => {
            let model = req
                .get("model")
                .as_str()
                .ok_or_else(|| ServeError::Protocol("'infer' needs a 'model'".to_string()))?;
            let inputs = req
                .get("inputs")
                .as_arr()
                .ok_or_else(|| ServeError::Protocol("'infer' needs 'inputs'".to_string()))?
                .iter()
                .map(json_tensor)
                .collect::<Result<Vec<NdArray>, ServeError>>()?;
            let outs = registry.infer(model, inputs)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("outputs", Json::Arr(outs.iter().map(tensor_json).collect())),
            ]))
        }
        "stats" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("models", registry.stats_json()),
        ])),
        "list" => {
            let names: Vec<Json> =
                registry.list().into_iter().map(|m| Json::str(m.name)).collect();
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Arr(names))]))
        }
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "health" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("health", registry.health_json()),
        ])),
        other => Err(ServeError::Protocol(format!("unknown verb '{other}'"))),
    }
}

// ---------------------------------------------------------------- server

/// Network front-end knobs.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Concurrent connections; the server replies `Overloaded` and
    /// closes anything past this.
    pub max_conns: usize,
    /// Read timeout used to poll the shutdown flag on idle
    /// connections.
    pub poll_interval: Duration,
    /// Whether the wire may DEPLOY/UNDEPLOY models.
    pub allow_deploy: bool,
    /// Cap on one JSON-fallback line in bytes (the binary protocol's
    /// counterpart to [`MAX_FRAME`]); a connection that buffers more
    /// than this without a newline gets a typed error and is closed.
    pub max_line: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 64,
            poll_interval: Duration::from_millis(25),
            allow_deploy: true,
            max_line: MAX_FRAME,
        }
    }
}

/// The TCP server: an accept loop plus one handler thread per
/// connection, all serving one shared [`Registry`]. Dropping (or
/// [`NetServer::shutdown`]) stops accepting, lets every handler
/// finish its in-flight request, and joins — the registry (and its
/// model pools) stays alive for its owner.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    registry: Arc<Registry>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7070"`; port 0 picks one — read
    /// it back from [`NetServer::local_addr`]) and start serving
    /// `registry`.
    pub fn bind(
        addr: &str,
        registry: Arc<Registry>,
        cfg: NetConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || accept_loop(listener, registry, stop, cfg))
        };
        Ok(NetServer { local_addr, stop, accept: Some(accept), registry })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Stop accepting, drain in-flight connection work, join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Restores the live-connection gauge when a handler thread exits —
/// by any path, including a panic mid-request. Without this, one
/// poisoned handler would permanently eat a connection slot.
struct LiveGuard(Arc<AtomicUsize>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
) {
    let live = Arc::new(AtomicUsize::new(0));
    let conns: Mutex<Vec<JoinHandle<()>>> = Mutex::new(Vec::new());
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                let mut held = conns.lock().expect("conn list");
                held.retain(|h| !h.is_finished());
                if live.load(Ordering::SeqCst) >= cfg.max_conns {
                    // typed connection-level shed, best effort
                    let _ = write_frame(
                        &mut stream,
                        &err_payload(&ServeError::Overloaded {
                            model: "<connections>".to_string(),
                            depth: cfg.max_conns,
                            cap: cfg.max_conns,
                        }),
                    );
                    continue;
                }
                live.fetch_add(1, Ordering::SeqCst);
                let registry = Arc::clone(&registry);
                let stop = Arc::clone(&stop);
                let live = Arc::clone(&live);
                let cfg = cfg.clone();
                held.push(std::thread::spawn(move || {
                    let _guard = LiveGuard(live);
                    // One connection's panic is that connection's
                    // problem: the socket drops (the client sees a
                    // reset), the guard restores the gauge, and the
                    // accept loop keeps serving everyone else.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                        handle_conn(stream, &registry, &stop, &cfg)
                    }));
                }));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in conns.into_inner().expect("conn list").drain(..) {
        let _ = h.join();
    }
}

/// One connection: sniff binary vs JSON from the first byte, then
/// loop request → reply until EOF or server shutdown. The read
/// timeout only exists so shutdown is observed; partial frames are
/// reassembled across timeouts.
fn handle_conn(
    mut stream: TcpStream,
    registry: &Registry,
    stop: &AtomicBool,
    cfg: &NetConfig,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(cfg.poll_interval))?;
    stream.set_nodelay(true)?;
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 16 * 1024];
    let mut json_mode: Option<bool> = None;
    loop {
        // serve everything already buffered
        loop {
            if json_mode.is_none() {
                json_mode = buf.first().map(|&b| b == b'{');
            }
            match json_mode {
                Some(true) => {
                    let Some(nl) = buf.iter().position(|&b| b == b'\n') else {
                        if buf.len() > cfg.max_line {
                            let e = ServeError::Protocol(format!(
                                "json line of {} bytes exceeds the {} cap",
                                buf.len(),
                                cfg.max_line
                            ));
                            stream.write_all((json_err(&e).to_string() + "\n").as_bytes())?;
                            return Ok(()); // framing is unrecoverable: close
                        }
                        break;
                    };
                    let line: Vec<u8> = buf.drain(..=nl).collect();
                    let line = String::from_utf8_lossy(&line[..nl]);
                    if line.trim().is_empty() {
                        continue;
                    }
                    let reply = handle_json_line(registry, line.trim());
                    stream.write_all((reply.to_string() + "\n").as_bytes())?;
                }
                Some(false) => {
                    if buf.len() < 4 {
                        break;
                    }
                    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
                    if len > MAX_FRAME {
                        write_frame(
                            &mut stream,
                            &err_payload(&ServeError::Protocol(format!(
                                "frame of {len} bytes exceeds the {MAX_FRAME} cap"
                            ))),
                        )?;
                        return Ok(()); // framing is unrecoverable: close
                    }
                    if buf.len() < 4 + len {
                        break;
                    }
                    let frame: Vec<u8> = buf.drain(..4 + len).skip(4).collect();
                    let mut resp = handle_binary(registry, &frame, cfg.allow_deploy);
                    faults::mangle(faults::Point::NetWrite, &mut resp)?;
                    write_frame(&mut stream, &resp)?;
                }
                None => break,
            }
        }
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        faults::io_gate(faults::Point::NetRead)?;
        match stream.read(&mut tmp) {
            Ok(0) => return Ok(()),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// Decode an INFER reply body (`n:u8, n x tensor`).
fn decode_outputs(body: &[u8]) -> Result<Vec<NdArray>, ServeError> {
    let mut w = Wire::new(body);
    let n = w.u8()? as usize;
    let mut outs = Vec::with_capacity(n);
    for _ in 0..n {
        outs.push(w.tensor()?);
    }
    Ok(outs)
}

// ---------------------------------------------------------------- client

/// A blocking client for the binary protocol — used by the load
/// generator (`nnl bench-serve --net`), the integration tests, and as
/// the reference implementation for other-language clients.
///
/// Transport faults (reset connections, truncated or malformed reply
/// frames) surface as [`ServeError::Protocol`] with a recognizable
/// prefix; [`NetClient::infer_with_retry`] reconnects and retries
/// exactly those plus [`ServeError::Overloaded`] — never `Internal`
/// or a verifier rejection, which retrying cannot fix.
pub struct NetClient {
    stream: TcpStream,
    addr: SocketAddr,
}

impl NetClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let addr = stream.peer_addr()?;
        Ok(NetClient { stream, addr })
    }

    /// Replace a stream that may hold half a reply with a fresh one —
    /// the only way to recover a frame boundary after a transport
    /// error.
    fn reconnect(&mut self) -> bool {
        match TcpStream::connect(self.addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                self.stream = s;
                true
            }
            Err(_) => false,
        }
    }

    /// Transport-shaped failures: the connection itself broke or the
    /// reply bytes cannot be a frame. These are the errors where the
    /// stream position is unknown and a retry must reconnect first.
    fn is_transport(e: &ServeError) -> bool {
        matches!(e, ServeError::Protocol(m)
            if m.starts_with("connection: ")
                || m.starts_with("malformed reply")
                || m.starts_with("oversized reply"))
    }

    fn wire_retryable(e: &ServeError) -> bool {
        matches!(e, ServeError::Overloaded { .. }) || NetClient::is_transport(e)
    }

    fn roundtrip(&mut self, payload: &[u8]) -> Result<Vec<u8>, ServeError> {
        let io = |e: std::io::Error| ServeError::Protocol(format!("connection: {e}"));
        write_frame(&mut self.stream, payload).map_err(io)?;
        let mut hdr = [0u8; 4];
        self.stream.read_exact(&mut hdr).map_err(io)?;
        let len = u32::from_le_bytes(hdr) as usize;
        if len > MAX_FRAME {
            return Err(ServeError::Protocol("oversized reply frame".to_string()));
        }
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload).map_err(io)?;
        Ok(payload)
    }

    /// Issue one request and decode the response header; returns a
    /// cursor positioned at the verb-specific body.
    fn request(&mut self, payload: &[u8]) -> Result<Vec<u8>, ServeError> {
        let resp = self.roundtrip(payload)?;
        let mut w = Wire::new(&resp);
        let status = w
            .u8()
            .and_then(|_version| w.u8())
            .map_err(|_| ServeError::Protocol("malformed reply: truncated header".to_string()))?;
        if status != 0 {
            let msg = w.str_().unwrap_or_else(|_| "malformed error reply".to_string());
            return Err(ServeError::from_wire(status, msg));
        }
        Ok(resp[w.pos..].to_vec())
    }

    pub fn infer(&mut self, model: &str, inputs: &[NdArray]) -> ServeResult {
        let mut p = vec![PROTO_VERSION, verb::INFER];
        put_str(&mut p, model);
        p.push(inputs.len() as u8);
        for a in inputs {
            put_tensor(&mut p, a);
        }
        let body = self.request(&p)?;
        // a reply that stops decoding mid-tensor is a transport fault
        // (truncated frame), not a server-side type error — mark it so
        // the retry path knows to reconnect
        decode_outputs(&body)
            .map_err(|e| ServeError::Protocol(format!("malformed reply: {e}")))
    }

    /// [`NetClient::infer`] with reconnection and jittered backoff on
    /// retryable failures; returns the outputs plus how many retries
    /// it took. Non-retryable errors (`Internal`, verifier rejections,
    /// `NoSuchModel`) return immediately — retrying cannot fix them.
    pub fn infer_with_retry(
        &mut self,
        model: &str,
        inputs: &[NdArray],
        policy: &RetryPolicy,
    ) -> Result<(Vec<NdArray>, usize), ServeError> {
        let mut attempt = 0usize;
        loop {
            match self.infer(model, inputs) {
                Ok(outs) => return Ok((outs, attempt)),
                Err(e) if attempt < policy.max_retries && NetClient::wire_retryable(&e) => {
                    std::thread::sleep(policy.backoff(attempt, attempt as u64));
                    if NetClient::is_transport(&e) && !self.reconnect() {
                        return Err(e);
                    }
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    pub fn stats(&mut self) -> Result<Json, ServeError> {
        let body = self.request(&[PROTO_VERSION, verb::STATS])?;
        let s = Wire::new(&body).str_()?;
        Json::parse(&s).map_err(ServeError::Protocol)
    }

    pub fn list(&mut self) -> Result<Json, ServeError> {
        let body = self.request(&[PROTO_VERSION, verb::LIST])?;
        let s = Wire::new(&body).str_()?;
        Json::parse(&s).map_err(ServeError::Protocol)
    }

    pub fn ping(&mut self) -> Result<(), ServeError> {
        self.request(&[PROTO_VERSION, verb::PING]).map(|_| ())
    }

    /// Readiness probe: `{"ready", "pool_restarts", "models": {name:
    /// {"ready", "workers_alive", "worker_restarts", ...}}}`.
    pub fn health(&mut self) -> Result<Json, ServeError> {
        let body = self.request(&[PROTO_VERSION, verb::HEALTH])?;
        let s = Wire::new(&body).str_()?;
        Json::parse(&s).map_err(ServeError::Protocol)
    }

    /// Push an NNB1/NNB2 image; returns `(version, kind)`.
    pub fn deploy(&mut self, model: &str, image: &[u8]) -> Result<(u64, String), ServeError> {
        let mut p = vec![PROTO_VERSION, verb::DEPLOY];
        put_str(&mut p, model);
        put_u32(&mut p, image.len() as u32);
        p.extend_from_slice(image);
        let body = self.request(&p)?;
        let s = Wire::new(&body).str_()?;
        let j = Json::parse(&s).map_err(ServeError::Protocol)?;
        let version = j
            .get("version")
            .as_usize()
            .ok_or_else(|| ServeError::Protocol("deploy reply missing version".to_string()))?;
        let kind = j.get("kind").as_str().unwrap_or("?").to_string();
        Ok((version as u64, kind))
    }

    pub fn undeploy(&mut self, model: &str) -> Result<(), ServeError> {
        let mut p = vec![PROTO_VERSION, verb::UNDEPLOY];
        put_str(&mut p, model);
        self.request(&p).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::tests::affine_plan;

    fn registry_with(names: &[(&str, &[f32])]) -> Arc<Registry> {
        let reg = Arc::new(Registry::new(ServeConfig::default()));
        for (n, w) in names {
            reg.deploy(n, affine_plan(w), "f32");
        }
        reg
    }

    #[test]
    fn wire_tensor_roundtrip() {
        let a = NdArray::from_slice(&[2, 3], &[1., -2., 3.5, 0., 5., -6.25]);
        let mut buf = Vec::new();
        put_tensor(&mut buf, &a);
        let b = Wire::new(&buf).tensor().unwrap();
        assert_eq!(a.dims(), b.dims());
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn wire_rejects_hostile_tensors() {
        // rank 0
        assert!(Wire::new(&[0u8]).tensor().is_err());
        // dim product overflowing the frame cap must fail before allocating
        let mut buf = vec![2u8];
        put_u32(&mut buf, u32::MAX);
        put_u32(&mut buf, u32::MAX);
        let err = Wire::new(&buf).tensor().unwrap_err();
        assert!(matches!(err, ServeError::Protocol(_)), "{err}");
        // truncated data
        let mut buf = vec![1u8];
        put_u32(&mut buf, 4);
        buf.extend_from_slice(&1.0f32.to_le_bytes()); // 1 of 4 values
        assert!(Wire::new(&buf).tensor().is_err());
    }

    #[test]
    fn registry_swap_is_versioned_and_atomic_to_observers() {
        let reg = registry_with(&[("m", &[1., 0., 0., 0., 1., 0.])]);
        assert_eq!(reg.version("m"), Some(1));
        let x = NdArray::from_slice(&[1, 2], &[3., 4.]);
        assert_eq!(reg.infer("m", vec![x.clone()]).unwrap()[0].data()[0], 3.);
        // hot swap to a doubled weight matrix
        let v = reg.deploy("m", affine_plan(&[2., 0., 0., 0., 2., 0.]), "f32");
        assert_eq!(v, 2);
        assert_eq!(reg.version("m"), Some(2));
        assert_eq!(reg.infer("m", vec![x]).unwrap()[0].data()[0], 6.);
        let stats = reg.stats_json();
        assert_eq!(stats.get("m").get("swaps").as_usize(), Some(1));
        assert_eq!(stats.get("m").get("requests").as_usize(), Some(2));
    }

    #[test]
    fn registry_miss_is_typed() {
        let reg = registry_with(&[]);
        let err = reg.infer("ghost", vec![]).unwrap_err();
        assert_eq!(err, ServeError::NoSuchModel("ghost".to_string()));
        assert!(!reg.remove("ghost"));
    }

    #[test]
    fn binary_frames_reject_bad_version_and_verb() {
        let reg = registry_with(&[]);
        let resp = handle_binary(&reg, &[9, verb::PING], true);
        assert_eq!(resp[1], ServeError::Protocol(String::new()).code());
        let resp = handle_binary(&reg, &[PROTO_VERSION, 200], true);
        assert_eq!(resp[1], 6);
        // truncated INFER must come back as a typed protocol error
        let resp = handle_binary(&reg, &[PROTO_VERSION, verb::INFER, 1], true);
        assert_eq!(resp[1], 6);
    }

    #[test]
    fn json_line_protocol_infer_and_errors() {
        let reg = registry_with(&[("m", &[1., 0., 0., 0., 1., 0.])]);
        let ok = handle_json_line(
            &reg,
            r#"{"verb":"infer","model":"m","inputs":[{"dims":[1,2],"data":[7.0,-1.0]}]}"#,
        );
        assert_eq!(ok.get("ok").as_bool(), Some(true));
        let out = &ok.get("outputs").as_arr().unwrap()[0];
        assert_eq!(out.get("dims").usize_arr().unwrap(), vec![1, 3]);
        assert_eq!(out.get("data").as_arr().unwrap()[0].as_f64(), Some(7.0));

        let miss = handle_json_line(
            &reg,
            r#"{"verb":"infer","model":"ghost","inputs":[{"dims":[1,2],"data":[0,0]}]}"#,
        );
        assert_eq!(miss.get("ok").as_bool(), Some(false));
        assert_eq!(miss.get("error").as_str(), Some("no_such_model"));

        let garbage = handle_json_line(&reg, "not json at all");
        assert_eq!(garbage.get("ok").as_bool(), Some(false));
        assert_eq!(garbage.get("error").as_str(), Some("protocol"));

        // shape mismatch between dims and data
        let bad = handle_json_line(
            &reg,
            r#"{"verb":"infer","model":"m","inputs":[{"dims":[1,2],"data":[1.0]}]}"#,
        );
        assert_eq!(bad.get("error").as_str(), Some("protocol"));
    }

    #[test]
    fn deploy_artifact_sniffs_and_rejects() {
        let reg = registry_with(&[]);
        let err = reg.deploy_artifact("m", b"definitely not an image").unwrap_err();
        assert!(matches!(err, ServeError::Protocol(_)), "{err}");
        // a real NNB1 image deploys as f32
        let (net, params) = crate::models::zoo::export_eval("mlp", 3);
        let image = crate::converters::nnb::to_nnb(&net, &params.into_iter().collect::<Vec<_>>());
        let (v, kind) = reg.deploy_artifact("mlp", &image).unwrap();
        assert_eq!((v, kind), (1, "f32"));
        assert!(reg.contains("mlp"));
    }

    #[test]
    fn deploy_rejects_artifact_failing_static_verification() {
        // Acceptance criterion: a corrupted-but-well-formed artifact must be
        // rejected by the DEPLOY path with a stable error code, before any
        // model swap becomes visible to clients.
        let reg = registry_with(&[]);
        let (net, params) = crate::models::zoo::export_eval("mlp", 3);
        let mut params: Vec<(String, NdArray)> = params.into_iter().collect();
        // Grow one weight matrix by a row: the image still decodes, but shape
        // inference over the graph no longer closes.
        let idx = params
            .iter()
            .position(|(_, a)| a.dims().len() == 2)
            .expect("mlp has a rank-2 weight");
        let d = params[idx].1.dims().to_vec();
        params[idx].1 = NdArray::zeros(&[d[0] + 1, d[1]]);
        let image = crate::converters::nnb::to_nnb(&net, &params);
        let err = reg.deploy_artifact("bad", &image).unwrap_err();
        assert!(matches!(err, ServeError::InvalidRequest(_)), "{err}");
        assert!(err.to_string().contains("NNL-E006"), "{err}");
        assert!(!reg.contains("bad"), "rejected model must not be swapped in");
    }

    #[test]
    fn health_reports_per_model_readiness() {
        let reg = registry_with(&[]);
        // an empty registry is not ready — nothing can serve
        assert_eq!(reg.health_json().get("ready").as_bool(), Some(false));
        reg.deploy("m", affine_plan(&[1., 0., 0., 0., 1., 0.]), "f32");
        let h = reg.health_json();
        assert_eq!(h.get("ready").as_bool(), Some(true));
        let m = h.get("models").get("m");
        assert_eq!(m.get("ready").as_bool(), Some(true));
        assert!(m.get("workers_alive").as_usize().unwrap() > 0);
        assert_eq!(m.get("worker_restarts").as_usize(), Some(0));
        // the HEALTH verb carries the same payload over both protocols
        let resp = handle_binary(&reg, &[PROTO_VERSION, verb::HEALTH], false);
        assert_eq!(resp[1], 0, "HEALTH must succeed");
        let j = handle_json_line(&reg, r#"{"verb":"health"}"#);
        assert_eq!(j.get("ok").as_bool(), Some(true));
        assert_eq!(j.get("health").get("ready").as_bool(), Some(true));
    }

    #[test]
    fn transport_errors_are_classified_for_retry() {
        let conn = ServeError::Protocol("connection: reset by peer".to_string());
        let malformed = ServeError::Protocol("malformed reply: truncated frame".to_string());
        let typed = ServeError::Protocol("unknown verb 99".to_string());
        assert!(NetClient::is_transport(&conn));
        assert!(NetClient::is_transport(&malformed));
        assert!(!NetClient::is_transport(&typed));
        assert!(NetClient::wire_retryable(&conn));
        assert!(NetClient::wire_retryable(&ServeError::Overloaded {
            model: "m".to_string(),
            depth: 8,
            cap: 8,
        }));
        assert!(!NetClient::wire_retryable(&ServeError::Internal("boom".to_string())));
        assert!(!NetClient::wire_retryable(&ServeError::NoSuchModel("m".to_string())));
    }
}
