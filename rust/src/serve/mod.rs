//! Batched multi-threaded inference serving on top of compiled plans
//! (ROADMAP north-star: serve heavy traffic as fast as the hardware
//! allows; paper §3.4: one trained NNP file, many runtimes).
//!
//! [`Server`] owns a worker pool sharing one plan behind the
//! [`InferencePlan`] trait — the f32 [`CompiledNet`] or the int8
//! [`crate::quant::QuantizedNet`], compiled once at load time through
//! the full graph-optimizer pipeline (`nnp::passes`, O2: BN folding,
//! no-op elision, dense→ReLU fusion, static memory plan) and
//! executed `&self` from every worker. Single-example requests are
//! **micro-batched**: a worker
//! takes the first queued request, then keeps draining the queue until
//! `max_batch` rows are gathered or `max_wait` elapses, concatenates
//! the inputs along axis 0, executes the plan once, and splits the
//! outputs back per request. Batching is only enabled when the plan is
//! provably row-independent ([`CompiledNet::batch_invariant`]);
//! otherwise every request runs alone — correctness never depends on
//! the batching heuristic, because batched outputs are sliced from the
//! same kernels a solo run would use.
//!
//! **Admission control.** The request queue is *bounded*
//! ([`ServeConfig::queue_cap`]; 0 derives a cap from the plan's static
//! memory plan — see [`derive_queue_cap`]). A full queue sheds the
//! request immediately with [`ServeError::Overloaded`] instead of
//! letting a slow plan grow memory without limit and time clients out.
//! Shutdown is graceful: closing the queue lets workers drain every
//! queued request and flush in-flight micro-batches before the pool
//! joins — no accepted request is ever silently dropped.
//!
//! All counters flow into a shared [`ModelMetrics`]
//! ([`crate::monitor::metrics`]): latency histograms (p50/p99),
//! batch-size distribution, shed counts, queue depth. The network
//! front end over this core — TCP protocol, multi-model registry, hot
//! reload — lives in [`net`].
//!
//! The CLI front ends are `nnl serve` (stdin request loop, or
//! `--listen` for the TCP server) and `nnl bench-serve`
//! (`--net` drives the TCP load generator); the numbers live in
//! `benches/serve_throughput.rs` and `benches/serve_net.rs`.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::faults;
use crate::monitor::metrics::ModelMetrics;
use crate::nnp::ir::NetworkDef;
use crate::nnp::plan::{CompiledNet, InferencePlan};
use crate::tensor::{NdArray, Rng};

pub mod net;

/// What a reply channel carries.
pub type ServeResult = Result<Vec<NdArray>, ServeError>;

/// Typed serving failures — every rejection a client can observe has
/// a distinct variant (and a stable wire code, [`ServeError::code`]),
/// so load-shedding is a *reply*, not a timeout.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Admission control: the model's bounded queue is full.
    Overloaded { model: String, depth: usize, cap: usize },
    /// The server (or the plan incarnation hosting this request) is
    /// shutting down and no longer accepts work.
    ShuttingDown,
    /// The request itself is malformed (wrong arity/shapes).
    InvalidRequest(String),
    /// The plan failed while executing.
    Execution(String),
    /// Registry lookup miss ([`net::Registry`]).
    NoSuchModel(String),
    /// Malformed bytes on the wire ([`net`] framing/encoding).
    Protocol(String),
    /// The request panicked inside a worker. The panic was caught at
    /// the isolation boundary, the worker's scratch arena was
    /// discarded, and only this request failed — but the failure is
    /// deterministic for these inputs, so clients must never retry it.
    Internal(String),
    /// The request's deadline expired while it waited in the queue; it
    /// was shed *before* compute ([`Client::submit_with_deadline`]).
    DeadlineExceeded { waited_ms: u64 },
}

impl ServeError {
    /// Stable one-byte wire code (0 is reserved for OK).
    pub fn code(&self) -> u8 {
        match self {
            ServeError::Overloaded { .. } => 1,
            ServeError::ShuttingDown => 2,
            ServeError::InvalidRequest(_) => 3,
            ServeError::Execution(_) => 4,
            ServeError::NoSuchModel(_) => 5,
            ServeError::Protocol(_) => 6,
            ServeError::Internal(_) => 7,
            ServeError::DeadlineExceeded { .. } => 8,
        }
    }

    /// Short machine-readable kind name (JSON replies, logs).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::InvalidRequest(_) => "invalid_request",
            ServeError::Execution(_) => "execution",
            ServeError::NoSuchModel(_) => "no_such_model",
            ServeError::Protocol(_) => "protocol",
            ServeError::Internal(_) => "internal",
            ServeError::DeadlineExceeded { .. } => "deadline_exceeded",
        }
    }

    /// Whether an *in-process* client may safely resubmit: only
    /// admission shedding ([`ServeError::Overloaded`]) is transient
    /// here. `Internal` (a panicking request), shape/verifier
    /// rejections, and execution failures are deterministic for the
    /// same inputs — retrying re-burns compute for the same answer.
    /// The wire client additionally retries transport-level failures;
    /// see [`net::NetClient::infer_with_retry`].
    pub fn retryable(&self) -> bool {
        matches!(self, ServeError::Overloaded { .. })
    }

    /// Rebuild from a wire `(code, message)` pair — the client-side
    /// inverse of [`ServeError::code`]/`Display`.
    pub fn from_wire(code: u8, msg: String) -> ServeError {
        match code {
            1 => ServeError::Overloaded { model: msg, depth: 0, cap: 0 },
            2 => ServeError::ShuttingDown,
            3 => ServeError::InvalidRequest(msg),
            4 => ServeError::Execution(msg),
            5 => ServeError::NoSuchModel(msg),
            7 => ServeError::Internal(msg),
            8 => {
                // Display renders "... waited N ms ..."; recover N.
                let waited_ms =
                    msg.split_whitespace().find_map(|t| t.parse().ok()).unwrap_or(0);
                ServeError::DeadlineExceeded { waited_ms }
            }
            _ => ServeError::Protocol(msg),
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { model, depth, cap } => write!(
                f,
                "model '{model}' overloaded: bounded queue full ({depth}/{cap}); retry later"
            ),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            ServeError::Execution(m) => write!(f, "execution failed: {m}"),
            ServeError::NoSuchModel(m) => write!(f, "no such model: '{m}'"),
            ServeError::Protocol(m) => write!(f, "protocol error: {m}"),
            ServeError::Internal(m) => write!(f, "internal error: {m}"),
            ServeError::DeadlineExceeded { waited_ms } => write!(
                f,
                "deadline exceeded: waited {waited_ms} ms in queue; shed before compute"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Worker-pool and micro-batching knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads sharing the plan.
    pub workers: usize,
    /// Maximum rows per executed batch (1 disables micro-batching).
    /// A hard cap for coalescing — though a single request carrying
    /// more rows than this still executes, alone.
    pub max_batch: usize,
    /// How long a worker waits for more requests to fill a batch.
    pub max_wait: Duration,
    /// Bounded queue capacity (admission control). 0 = derive from
    /// the plan's static memory plan ([`derive_queue_cap`]).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 0,
        }
    }
}

/// Arena-byte budget the automatic queue cap spends: with a known
/// `peak_arena_bytes` per queued request (each queued request is at
/// worst one more plan execution's working set), the queue may hold at
/// most `QUEUE_BYTE_BUDGET / peak` requests, clamped to
/// `[MIN_QUEUE_CAP, MAX_QUEUE_CAP]`.
pub const QUEUE_BYTE_BUDGET: usize = 256 << 20;
pub const MIN_QUEUE_CAP: usize = 8;
pub const MAX_QUEUE_CAP: usize = 512;

/// Derive a bounded-queue capacity for `plan` from its static memory
/// plan: models with a large per-execution working set admit fewer
/// queued requests. Plans without a memory plan (interpreted /
/// quantized fallbacks compiled at O0) get `MAX_QUEUE_CAP / 8`.
pub fn derive_queue_cap(plan: &dyn InferencePlan) -> usize {
    match plan.peak_arena_bytes() {
        Some(peak) if peak > 0 => {
            (QUEUE_BYTE_BUDGET / peak).clamp(MIN_QUEUE_CAP, MAX_QUEUE_CAP)
        }
        _ => MAX_QUEUE_CAP / 8,
    }
}

/// Client-side retry policy: jittered exponential backoff, seeded so
/// tests replay identically. Used by [`Client::infer_with_retry`] and
/// [`net::NetClient::infer_with_retry`]. Retry *eligibility* is the
/// caller's contract ([`ServeError::retryable`] in process, plus
/// transport errors on the wire) — the policy only shapes the
/// schedule.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 disables retry).
    pub max_retries: usize,
    /// Backoff before the first retry; doubles per attempt.
    pub base: Duration,
    /// Ceiling for the exponential growth.
    pub cap: Duration,
    /// Jitter seed — same seed, same backoff schedule.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(100),
            seed: 7,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based): `base * 2^attempt`
    /// clamped to `cap`, then half-to-full jittered — spreading
    /// synchronized retry storms while never sleeping less than half
    /// the deterministic schedule. `salt` decorrelates concurrent
    /// clients sharing one policy.
    pub fn backoff(&self, attempt: usize, salt: u64) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16) as u32);
        let ceil = exp.min(self.cap).max(Duration::from_micros(100));
        let h = faults::splitmix64(
            self.seed ^ salt.rotate_left(17) ^ ((attempt as u64) << 32),
        );
        let frac = (h >> 11) as f64 / (1u64 << 53) as f64;
        ceil.mul_f64(0.5 + 0.5 * frac)
    }
}

/// One queued inference request: positional inputs + reply channel.
struct Request {
    inputs: Vec<NdArray>,
    rows: usize,
    enqueued: Instant,
    /// Shed with [`ServeError::DeadlineExceeded`] if still queued past
    /// this instant ([`Client::submit_with_deadline`]).
    deadline: Option<Instant>,
    reply: Sender<ServeResult>,
}

/// The shared request queue: a Condvar-guarded **bounded** deque (not
/// `mpsc`) so a worker parked waiting for work releases the lock while
/// it sleeps — a draining worker can always make progress, and
/// `close()` lets workers finish the backlog and exit even while
/// `Client` handles are still alive. A full queue rejects instead of
/// blocking: backpressure surfaces as [`ServeError::Overloaded`] at
/// submit time, never as an unbounded memory ramp.
struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
    cap: usize,
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Poisoning-safe lock: no worker holds the queue mutex across
    /// user code, but chaos exists to check "never" — a thread that
    /// somehow panicked at a lock-release point must not wedge every
    /// other worker and client forever. The state is a plain deque +
    /// flag, consistent at every release point, so recovering the
    /// guard is sound.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue, failing cleanly once the server shut down or the
    /// bounded queue is full (the caller owns `req.reply` error
    /// delivery via the returned error).
    fn push(&self, model: &str, req: Request) -> Result<(), ServeError> {
        let mut st = self.lock_state();
        if st.closed {
            return Err(ServeError::ShuttingDown);
        }
        if st.items.len() >= self.cap {
            return Err(ServeError::Overloaded {
                model: model.to_string(),
                depth: st.items.len(),
                cap: self.cap,
            });
        }
        st.items.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    fn pop(&self) -> Option<Request> {
        let mut st = self.lock_state();
        loop {
            if let Some(r) = st.items.pop_front() {
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Pop with a deadline, taking the head request only if it fits in
    /// `row_budget` (keeps `max_batch` a hard cap while preserving FIFO
    /// order); `None` on timeout, closed-and-drained, or a head too
    /// large for this batch.
    fn pop_until(&self, deadline: Instant, row_budget: usize) -> Option<Request> {
        let mut st = self.lock_state();
        loop {
            if let Some(front) = st.items.front() {
                if front.rows > row_budget {
                    return None; // leave it to start its own batch
                }
                return st.items.pop_front();
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            st = self.cv.wait_timeout(st, deadline - now).unwrap_or_else(|e| e.into_inner()).0;
        }
    }

    /// Stop accepting work and wake every parked worker. Queued
    /// requests stay — workers drain them to completion before
    /// exiting, which is what makes shutdown graceful.
    fn close(&self) {
        self.lock_state().closed = true;
        self.cv.notify_all();
    }
}

/// Snapshot of server throughput/latency counters (a rendering of
/// [`crate::monitor::metrics::MetricsSnapshot`] kept for the CLI and
/// benches).
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub rows: u64,
    /// Plan executions (each may cover several requests).
    pub batches: u64,
    pub errors: u64,
    /// Requests refused by admission control.
    pub shed: u64,
    /// Request panics caught at the worker isolation boundary.
    pub panics_caught: u64,
    /// Workers resurrected by supervision.
    pub worker_restarts: u64,
    /// Requests shed before compute because their deadline expired.
    pub deadline_expired: u64,
    /// In-process client retries ([`Client::infer_with_retry`]).
    pub retries: u64,
    pub mean_batch_rows: f64,
    /// Mean wall time inside `CompiledNet::execute` per batch.
    pub mean_exec_ms: f64,
    /// Mean enqueue-to-reply latency per request.
    pub mean_latency_ms: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests ({} rows) in {} batches (mean {:.2} rows/batch), \
             mean exec {:.3} ms/batch, latency mean {:.3} / p50 {:.3} / p99 {:.3} ms, \
             {} errors, {} shed",
            self.requests,
            self.rows,
            self.batches,
            self.mean_batch_rows,
            self.mean_exec_ms,
            self.mean_latency_ms,
            self.p50_latency_ms,
            self.p99_latency_ms,
            self.errors,
            self.shed
        )
    }
}

/// A running inference server: worker pool + shared compiled plan.
/// Dropping (or [`Server::shutdown`]) closes the queue, drains every
/// queued request, flushes in-flight micro-batches, and joins the
/// workers — no accepted request is silently dropped.
pub struct Server {
    plan: Arc<dyn InferencePlan>,
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<ModelMetrics>,
    batched: bool,
}

impl Server {
    /// Start `cfg.workers` threads serving `plan` (any
    /// [`InferencePlan`] — the f32 compiled plan or a quantized one).
    pub fn start<P: InferencePlan + 'static>(plan: Arc<P>, cfg: ServeConfig) -> Server {
        Server::start_dyn(plan, cfg)
    }

    /// Type-erased [`Server::start`] — the entry the CLI uses when the
    /// plan's concrete type is only known at run time (`.nnp` vs
    /// NNB/NNB2 artifacts).
    pub fn start_dyn(plan: Arc<dyn InferencePlan>, cfg: ServeConfig) -> Server {
        Server::start_shared(plan, cfg, Arc::new(ModelMetrics::default()))
    }

    /// Start with an externally-owned metrics sink — how the
    /// [`net::Registry`] keeps one [`ModelMetrics`] alive across hot
    /// swaps of the plan under a model name.
    pub fn start_shared(
        plan: Arc<dyn InferencePlan>,
        cfg: ServeConfig,
        metrics: Arc<ModelMetrics>,
    ) -> Server {
        let cap = if cfg.queue_cap > 0 {
            cfg.queue_cap
        } else {
            derive_queue_cap(plan.as_ref())
        };
        let queue = Arc::new(Queue::new(cap));
        // batching needs provably row-independent semantics
        let batched = cfg.max_batch > 1 && !plan.inputs().is_empty() && plan.batch_invariant();
        let n = cfg.workers.max(1);
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let queue = Arc::clone(&queue);
            let plan = Arc::clone(&plan);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                // Supervised worker: a panic that escapes the
                // per-request isolation boundary (an injected `worker`
                // fault, a bug outside execute) lands here. The
                // thread discards its scratch arena — a request that
                // unwound mid-kernel must not leak state into the
                // next one — counts the restart, and re-enters the
                // loop, so a worker slot never stays dead. A normal
                // return (queue closed and drained) exits.
                loop {
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        worker_loop(plan.as_ref(), &queue, &metrics, &cfg, batched)
                    }));
                    if run.is_ok() {
                        break;
                    }
                    crate::tensor::kernels::purge_scratch();
                    metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        Server { plan, queue, workers, metrics, batched }
    }

    /// The shared plan.
    pub fn plan(&self) -> &dyn InferencePlan {
        self.plan.as_ref()
    }

    /// Whether micro-batching is active for this plan/config.
    pub fn batched(&self) -> bool {
        self.batched
    }

    /// The bounded queue's capacity (admission-control limit).
    pub fn queue_cap(&self) -> usize {
        self.queue.cap
    }

    /// Workers currently alive (thread not finished). Supervision
    /// resurrects a panicked worker in place, so in steady state this
    /// equals the configured worker count; it only drops to zero
    /// during shutdown. Health probes use it as the "not wedged"
    /// signal.
    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|h| !h.is_finished()).count()
    }

    /// The live metrics sink.
    pub fn metrics(&self) -> &Arc<ModelMetrics> {
        &self.metrics
    }

    /// A cheap cloneable handle for submitting from other threads. A
    /// `Client` does not keep the server alive: after shutdown its
    /// submissions fail cleanly (and workers exit regardless of how
    /// many handles remain). The handle shares the server's *bounded*
    /// queue — a slow plan backs up into typed
    /// [`ServeError::Overloaded`] replies, never unbounded memory.
    pub fn client(&self) -> Client {
        Client {
            plan: Arc::clone(&self.plan),
            queue: Arc::clone(&self.queue),
            metrics: Arc::clone(&self.metrics),
            batched: self.batched,
        }
    }

    /// Enqueue a request (inputs in declared order; axis 0 free).
    /// Returns the reply channel immediately — shape errors are
    /// rejected here, before they can poison a batch, and a full
    /// queue sheds with [`ServeError::Overloaded`].
    pub fn submit(&self, inputs: Vec<NdArray>) -> Result<Receiver<ServeResult>, ServeError> {
        submit_on(self.plan.as_ref(), self.batched, &self.queue, &self.metrics, inputs, None)
    }

    /// [`Server::submit`] with a per-request deadline: if the request
    /// is still queued when `timeout` elapses, a worker sheds it
    /// *before* compute with [`ServeError::DeadlineExceeded`] — a
    /// latency-sensitive caller never pays (and never makes the
    /// server pay) for an answer it would discard. A request already
    /// executing when its deadline passes finishes normally: the
    /// deadline gates queue wait, not compute.
    pub fn submit_with_deadline(
        &self,
        inputs: Vec<NdArray>,
        timeout: Duration,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        submit_on(
            self.plan.as_ref(),
            self.batched,
            &self.queue,
            &self.metrics,
            inputs,
            Some(Instant::now() + timeout),
        )
    }

    /// Blocking convenience: submit and wait for the outputs.
    pub fn infer(&self, inputs: Vec<NdArray>) -> ServeResult {
        let rx = self.submit(inputs)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// Blocking classification: argmax of each row of the first output.
    /// Uses the NaN-safe total ordering shared with trainer validation
    /// ([`crate::tensor::ops::argmax`]) — NaN logits cost accuracy, not
    /// a worker thread.
    pub fn infer_class(&self, inputs: Vec<NdArray>) -> Result<Vec<usize>, ServeError> {
        let out = self.infer(inputs)?;
        let first = out
            .first()
            .ok_or_else(|| ServeError::Execution("network has no outputs".to_string()))?;
        let rows = first.dims().first().copied().unwrap_or(1).max(1);
        let stride = first.size() / rows;
        if stride == 0 {
            return Err(ServeError::Execution("output rows are empty".to_string()));
        }
        Ok((0..rows)
            .map(|r| crate::tensor::ops::argmax(&first.data()[r * stride..(r + 1) * stride]))
            .collect())
    }

    /// Current counters.
    pub fn stats(&self) -> ServeStats {
        let s = self.metrics.snapshot();
        ServeStats {
            requests: s.requests,
            rows: s.rows,
            batches: s.batches,
            errors: s.errors,
            shed: s.shed,
            panics_caught: s.panics_caught,
            worker_restarts: s.worker_restarts,
            deadline_expired: s.deadline_expired,
            retries: s.retries,
            mean_batch_rows: s.mean_batch_rows,
            mean_exec_ms: s.mean_exec_ms,
            mean_latency_ms: s.mean_latency_ms,
            p50_latency_ms: s.p50_ms,
            p99_latency_ms: s.p99_ms,
        }
    }

    /// Close the queue, finish queued work, join the workers, and
    /// return the final counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        // graceful: workers drain the backlog (every queued request
        // gets a reply) before the join returns
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A submit-side handle to a running [`Server`]. Clone one per client
/// thread. A `Client` never blocks server shutdown; once the server is
/// gone its submissions fail cleanly with
/// [`ServeError::ShuttingDown`].
#[derive(Clone)]
pub struct Client {
    plan: Arc<dyn InferencePlan>,
    queue: Arc<Queue>,
    metrics: Arc<ModelMetrics>,
    batched: bool,
}

impl Client {
    /// Same contract as [`Server::submit`].
    pub fn submit(&self, inputs: Vec<NdArray>) -> Result<Receiver<ServeResult>, ServeError> {
        submit_on(self.plan.as_ref(), self.batched, &self.queue, &self.metrics, inputs, None)
    }

    /// Same contract as [`Server::submit_with_deadline`].
    pub fn submit_with_deadline(
        &self,
        inputs: Vec<NdArray>,
        timeout: Duration,
    ) -> Result<Receiver<ServeResult>, ServeError> {
        submit_on(
            self.plan.as_ref(),
            self.batched,
            &self.queue,
            &self.metrics,
            inputs,
            Some(Instant::now() + timeout),
        )
    }

    /// Same contract as [`Server::infer`].
    pub fn infer(&self, inputs: Vec<NdArray>) -> ServeResult {
        let rx = self.submit(inputs)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// [`Client::infer`] with retry for transient rejections
    /// ([`ServeError::retryable`] — admission shedding only): sleeps
    /// per `policy`'s jittered backoff, bumps the model's `retries`
    /// counter, and returns the last error once the budget is spent.
    /// `Internal`, shape, and execution errors return immediately —
    /// they are deterministic, retrying them only burns compute.
    pub fn infer_with_retry(&self, inputs: Vec<NdArray>, policy: &RetryPolicy) -> ServeResult {
        let mut attempt = 0usize;
        loop {
            match self.submit(inputs.clone()) {
                Ok(rx) => return rx.recv().map_err(|_| ServeError::ShuttingDown)?,
                Err(e) if e.retryable() && attempt < policy.max_retries => {
                    self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(policy.backoff(attempt, 0));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Shared submit path: validate shapes, wrap with a reply channel,
/// enqueue against the bounded queue (sheds when full).
fn submit_on(
    plan: &dyn InferencePlan,
    batched: bool,
    queue: &Queue,
    metrics: &ModelMetrics,
    inputs: Vec<NdArray>,
    deadline: Option<Instant>,
) -> Result<Receiver<ServeResult>, ServeError> {
    let rows = plan.check_inputs(&inputs).map_err(ServeError::InvalidRequest)?;
    if batched && !inputs.iter().all(|a| a.dims().first().copied() == Some(rows)) {
        return Err(ServeError::InvalidRequest(
            "all inputs of one request must share the batch dimension".to_string(),
        ));
    }
    faults::disrupt(faults::Point::QueueAdmit);
    let (reply, rx) = channel();
    // Gauge before push: a worker may pop (and decrement) the instant
    // push releases the lock, so incrementing afterwards would let the
    // u64 gauge transiently wrap below zero.
    metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    match queue
        .push(plan.name(), Request { inputs, rows, enqueued: Instant::now(), deadline, reply })
    {
        Ok(()) => Ok(rx),
        Err(e) => {
            metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            if matches!(e, ServeError::Overloaded { .. }) {
                metrics.shed.fetch_add(1, Ordering::Relaxed);
            }
            Err(e)
        }
    }
}

/// Requests a worker has popped but not yet answered. If anything
/// unwinds while requests are held here (an injected `worker` fault, a
/// bug outside the per-request boundary), the drop still answers each
/// one with a typed `Internal` — the exactly-one-reply invariant
/// survives the panic, and supervision restarts the worker.
struct InFlight<'a> {
    metrics: &'a ModelMetrics,
    reqs: Vec<Request>,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        for req in self.reqs.drain(..) {
            finish(
                self.metrics,
                req,
                Err(ServeError::Internal(
                    "worker panicked while this request was in flight".to_string(),
                )),
            );
        }
    }
}

/// Answer `req` with [`ServeError::DeadlineExceeded`] if its deadline
/// passed while it sat in the queue — shedding *before* compute is the
/// whole point — otherwise hand it back for execution.
fn shed_expired(metrics: &ModelMetrics, req: Request) -> Option<Request> {
    match req.deadline {
        Some(d) if Instant::now() >= d => {
            let waited_ms = req.enqueued.elapsed().as_millis() as u64;
            metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            finish(metrics, req, Err(ServeError::DeadlineExceeded { waited_ms }));
            None
        }
        _ => Some(req),
    }
}

fn worker_loop(
    plan: &dyn InferencePlan,
    queue: &Queue,
    metrics: &ModelMetrics,
    cfg: &ServeConfig,
    batched: bool,
) {
    // pop() parks on the condvar with the lock released, so workers
    // never block each other while idle
    while let Some(first) = queue.pop() {
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let Some(first) = shed_expired(metrics, first) else { continue };
        let mut flight = InFlight { metrics, reqs: vec![first] };
        if batched {
            let mut rows = flight.reqs[0].rows;
            let deadline = Instant::now() + cfg.max_wait;
            while rows < cfg.max_batch {
                match queue.pop_until(deadline, cfg.max_batch - rows) {
                    Some(r) => {
                        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        // an expired request is answered and dropped
                        // here; the rest of the batch proceeds
                        if let Some(r) = shed_expired(metrics, r) {
                            rows += r.rows;
                            flight.reqs.push(r);
                        }
                    }
                    None => break, // deadline, closed, or next one too big
                }
            }
        }
        faults::disrupt(faults::Point::WorkerLoop);
        run_batch(plan, metrics, &mut flight.reqs);
    }
}

fn run_batch(plan: &dyn InferencePlan, metrics: &ModelMetrics, batch: &mut Vec<Request>) {
    if batch.len() <= 1 {
        if let Some(req) = batch.pop() {
            run_single(plan, metrics, req);
        }
        return;
    }
    // concatenate each declared input across requests along axis 0
    let n_inputs = plan.inputs().len();
    let mut cat: Vec<NdArray> = Vec::with_capacity(n_inputs);
    for i in 0..n_inputs {
        let parts: Vec<&NdArray> = batch.iter().map(|r| &r.inputs[i]).collect();
        cat.push(NdArray::concat(&parts, 0));
    }
    let total: usize = batch.iter().map(|r| r.rows).sum();
    let t0 = Instant::now();
    let out = execute_caught(plan, metrics, &cat);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    match out {
        Err(e) => {
            metrics.record_batch(total, exec_ns);
            for req in batch.drain(..) {
                finish(metrics, req, Err(e.clone()));
            }
        }
        Ok(outs) => {
            if outs.iter().any(|o| o.dims().first().copied() != Some(total)) {
                // batch-invariance heuristic miss: discard the batched
                // run (it is not counted) and answer each request from
                // its own solo execution instead
                for req in batch.drain(..) {
                    run_single(plan, metrics, req);
                }
                return;
            }
            metrics.record_batch(total, exec_ns);
            let mut off = 0usize;
            for req in batch.drain(..) {
                let rows = req.rows;
                let slices: Vec<NdArray> =
                    outs.iter().map(|o| o.slice_axis(0, off, off + rows)).collect();
                off += rows;
                finish(metrics, req, Ok(slices));
            }
        }
    }
}

fn run_single(plan: &dyn InferencePlan, metrics: &ModelMetrics, req: Request) {
    let t0 = Instant::now();
    let out = execute_caught(plan, metrics, &req.inputs);
    metrics.record_batch(req.rows, t0.elapsed().as_nanos() as u64);
    finish(metrics, req, out);
}

/// Run the plan inside the per-request isolation boundary: execution
/// errors stay typed, and a panic — injected or real — becomes
/// [`ServeError::Internal`] after the worker's scratch arena is
/// discarded (a request that unwound mid-kernel must never leak
/// half-written buffers into the next request on this thread).
fn execute_caught(
    plan: &dyn InferencePlan,
    metrics: &ModelMetrics,
    inputs: &[NdArray],
) -> Result<Vec<NdArray>, ServeError> {
    let caught = catch_unwind(AssertUnwindSafe(|| {
        faults::disrupt(faults::Point::WorkerExec);
        plan.execute_positional(inputs)
    }));
    match caught {
        Ok(Ok(outs)) => Ok(outs),
        Ok(Err(e)) => Err(ServeError::Execution(e)),
        Err(payload) => {
            crate::tensor::kernels::purge_scratch();
            metrics.panics_caught.fetch_add(1, Ordering::Relaxed);
            Err(ServeError::Internal(panic_message(payload.as_ref())))
        }
    }
}

/// Best-effort panic payload rendering (`&str` and `String` cover
/// every `panic!` in practice).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn finish(metrics: &ModelMetrics, req: Request, out: ServeResult) {
    metrics.record_request(req.rows, req.enqueued.elapsed().as_nanos() as u64, out.is_err());
    // the client may have hung up; that is its problem, not ours
    let _ = req.reply.send(out);
}

/// The serving-throughput harness shared by `nnl bench-serve` and
/// `benches/serve_throughput.rs`: over `requests` random
/// single-example requests, measure per-request interpretation,
/// compiled-sequential execution, and worker-pool serving without and
/// with micro-batching. Returns the rendered report.
pub fn bench_throughput(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    requests: usize,
    cfg: &ServeConfig,
) -> Result<String, String> {
    use crate::utils::bench::{bench, table};
    let plan = Arc::new(CompiledNet::compile(net, params)?);
    let mut rng = Rng::new(7);
    let reqs: Vec<Vec<NdArray>> = (0..requests)
        .map(|_| {
            net.inputs
                .iter()
                .map(|t| {
                    let mut d = t.dims.clone();
                    if !d.is_empty() {
                        d[0] = 1;
                    }
                    rng.rand(&d, -1.0, 1.0)
                })
                .collect()
        })
        .collect();
    let named: Vec<HashMap<String, NdArray>> = reqs
        .iter()
        .map(|r| net.inputs.iter().map(|t| t.name.clone()).zip(r.iter().cloned()).collect())
        .collect();

    // 1. the old deployment path: full interpret (compile) per request
    let interp = bench("interpreter::run per request", 1, 3, || {
        for m in &named {
            crate::nnp::interpreter::run(net, m, params).expect("interpreted run");
        }
    });
    // 2. compile once, execute per request (params bound up front)
    let compiled = bench("compiled plan, sequential", 1, 3, || {
        for r in &reqs {
            plan.execute_positional(r).expect("compiled run");
        }
    });
    // 3./4. worker pool, request-at-a-time vs micro-batched: a load
    // generator submits everything, then awaits every reply — the
    // queue cap is lifted to the request count so the harness measures
    // throughput, not its own shedding
    let drive = |server: &Server| {
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone()).expect("submit")).collect();
        for rx in rxs {
            rx.recv().expect("server reply").expect("inference ok");
        }
    };
    let workers = cfg.workers.max(1);
    let open_cfg = ServeConfig { queue_cap: requests.max(1), ..cfg.clone() };
    let unbatched =
        Server::start(Arc::clone(&plan), ServeConfig { max_batch: 1, ..open_cfg.clone() });
    let un_m = bench(&format!("server x{workers}, unbatched"), 1, 3, || drive(&unbatched));
    let batched = Server::start(Arc::clone(&plan), open_cfg.clone());
    let b_m = bench(&format!("server x{workers}, max batch {}", open_cfg.max_batch), 1, 3, || {
        drive(&batched)
    });

    let rows = vec![interp, compiled, un_m, b_m];
    let mut out =
        table(&format!("Serving throughput: '{}' x {requests} requests", net.name), &rows);
    for r in &rows {
        out.push_str(&format!(
            "  {:<38} {:>10.0} requests/s\n",
            r.name,
            requests as f64 / r.mean_secs
        ));
    }
    out.push_str(&format!("batched server: {}\n", batched.shutdown()));
    out.push_str(&format!("unbatched server: {}\n", unbatched.shutdown()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
    use std::collections::HashMap;

    pub(crate) fn affine_plan(w: &[f32]) -> Arc<CompiledNet> {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "fc".into(),
                op: Op::Affine,
                inputs: vec!["x".into()],
                params: vec!["W".into()],
                outputs: vec!["y".into()],
            }],
        };
        let mut params = HashMap::new();
        params.insert("W".to_string(), NdArray::from_slice(&[2, 3], w));
        Arc::new(CompiledNet::compile(&net, &params).unwrap())
    }

    /// An [`InferencePlan`] decorator that sleeps inside every
    /// execution — the deterministic way to make a queue back up in
    /// admission-control and graceful-shutdown tests.
    pub(crate) struct SlowPlan<P: InferencePlan> {
        pub inner: P,
        pub delay: Duration,
    }

    impl<P: InferencePlan> InferencePlan for SlowPlan<P> {
        fn name(&self) -> &str {
            self.inner.name()
        }
        fn inputs(&self) -> &[TensorDef] {
            self.inner.inputs()
        }
        fn outputs(&self) -> &[String] {
            self.inner.outputs()
        }
        fn n_steps(&self) -> usize {
            self.inner.n_steps()
        }
        fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
            self.inner.check_inputs(inputs)
        }
        fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
            std::thread::sleep(self.delay);
            self.inner.execute_positional(inputs)
        }
        fn batch_invariant(&self) -> bool {
            self.inner.batch_invariant()
        }
        fn peak_arena_bytes(&self) -> Option<usize> {
            self.inner.peak_arena_bytes()
        }
    }

    #[test]
    fn serves_requests_and_matches_direct_execution() {
        let plan = affine_plan(&[1., 2., 3., 4., 5., 6.]);
        let server = Server::start(Arc::clone(&plan), ServeConfig::default());
        assert!(server.batched());
        let mut handles = Vec::new();
        for i in 0..16 {
            let x = NdArray::from_slice(&[1, 2], &[i as f32, -(i as f32)]);
            handles.push((x.clone(), server.submit(vec![x]).unwrap()));
        }
        for (x, rx) in handles {
            let got = rx.recv().unwrap().unwrap();
            let want = plan.execute_positional(&[x]).unwrap();
            assert_eq!(got[0].dims(), want[0].dims());
            assert_eq!(got[0].data(), want[0].data());
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 16);
        assert_eq!(stats.rows, 16);
        assert!(stats.batches <= 16);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.shed, 0);
    }

    #[test]
    fn non_batchable_plan_served_per_request() {
        let net = NetworkDef {
            name: "sum".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "s".into(),
                op: Op::SumAll,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = Arc::new(CompiledNet::compile(&net, &HashMap::new()).unwrap());
        let server = Server::start(Arc::clone(&plan), ServeConfig::default());
        assert!(!server.batched());
        let out = server.infer(vec![NdArray::from_slice(&[1, 2], &[3., 4.])]).unwrap();
        assert_eq!(out[0].data(), &[7.]);
    }

    #[test]
    fn bad_shapes_rejected_at_submit() {
        let plan = affine_plan(&[1., 2., 3., 4., 5., 6.]);
        let server = Server::start(plan, ServeConfig::default());
        let err = server.submit(vec![NdArray::zeros(&[2])]).unwrap_err();
        assert!(matches!(err, ServeError::InvalidRequest(_)), "{err}");
        assert!(err.to_string().contains("incompatible"), "{err}");
        let err = server.submit(vec![]).unwrap_err();
        assert!(err.to_string().contains("expects 1 inputs"), "{err}");
    }

    #[test]
    fn full_queue_sheds_with_typed_overload() {
        // one worker stuck 100 ms per request + a 2-slot queue: burst
        // submissions past (in-flight + 2) must shed, not queue forever
        let plan = Arc::new(SlowPlan {
            inner: Arc::try_unwrap(affine_plan(&[1., 0., 0., 0., 1., 0.]))
                .unwrap_or_else(|_| unreachable!()),
            delay: Duration::from_millis(100),
        });
        let cfg = ServeConfig { workers: 1, max_batch: 1, queue_cap: 2, ..Default::default() };
        let server = Server::start(plan, cfg);
        assert_eq!(server.queue_cap(), 2);
        let client = server.client();
        let mut oks = Vec::new();
        let mut shed = 0usize;
        for i in 0..12 {
            let x = NdArray::from_slice(&[1, 2], &[i as f32, 0.]);
            match client.submit(vec![x]) {
                Ok(rx) => oks.push(rx),
                Err(e @ ServeError::Overloaded { .. }) => {
                    assert_eq!(e.code(), 1);
                    assert!(e.to_string().contains("queue full"), "{e}");
                    shed += 1;
                }
                Err(other) => panic!("unexpected rejection: {other}"),
            }
        }
        assert!(shed >= 1, "burst of 12 into a 2-slot queue must shed");
        // every admitted request still completes (graceful drain)
        for rx in oks {
            rx.recv().expect("admitted request must be answered").unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.shed, shed as u64);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn graceful_shutdown_answers_every_admitted_request() {
        // the drain regression test: submit a backlog against a slow
        // plan, then drop the server immediately — every admitted
        // request must still receive an Ok reply (none silently
        // dropped, none errored)
        let plan = Arc::new(SlowPlan {
            inner: Arc::try_unwrap(affine_plan(&[2., 0., 0., 0., 2., 0.]))
                .unwrap_or_else(|_| unreachable!()),
            delay: Duration::from_millis(5),
        });
        let cfg = ServeConfig { workers: 2, queue_cap: 64, ..Default::default() };
        let server = Server::start(plan, cfg);
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                server
                    .submit(vec![NdArray::from_slice(&[1, 2], &[i as f32, 1.])])
                    .expect("queue has room")
            })
            .collect();
        drop(server); // closes queue, drains, joins
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx
                .recv()
                .expect("reply channel must not disconnect during shutdown")
                .expect("drained request must succeed");
            assert_eq!(out[0].data()[0], 2. * i as f32);
        }
    }

    #[test]
    fn submissions_after_shutdown_fail_typed() {
        let plan = affine_plan(&[1., 0., 0., 0., 1., 0.]);
        let server = Server::start(plan, ServeConfig::default());
        let client = server.client();
        drop(server);
        let err = client.submit(vec![NdArray::zeros(&[1, 2])]).unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }

    #[test]
    fn queue_cap_derived_from_memory_plan() {
        let plan = affine_plan(&[1., 0., 0., 0., 1., 0.]);
        let server = Server::start(Arc::clone(&plan), ServeConfig::default());
        // a tiny affine plan has a tiny arena -> cap clamps to the max
        assert_eq!(server.queue_cap(), MAX_QUEUE_CAP);
        assert_eq!(derive_queue_cap(plan.as_ref()), MAX_QUEUE_CAP);
    }

    #[test]
    fn nan_logits_classify_without_panicking() {
        // second class scores NaN for every input; prediction must fall
        // back to the best finite logit instead of killing a worker
        let plan = affine_plan(&[1., f32::NAN, 0., 1., f32::NAN, 0.]);
        let server = Server::start(plan, ServeConfig::default());
        let classes =
            server.infer_class(vec![NdArray::from_slice(&[2, 2], &[5., 1., 0., 2.])]).unwrap();
        assert_eq!(classes, vec![0, 0]);
    }

    #[test]
    fn server_hosts_quantized_plans() {
        use crate::quant::{quantize_net, QuantConfig};
        use crate::tensor::Rng;
        let net = NetworkDef {
            name: "q".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut rng = Rng::new(31);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[4, 3], 1.0));
        let samples: Vec<Vec<NdArray>> =
            (0..4).map(|_| vec![rng.rand(&[1, 4], -1.0, 1.0)]).collect();
        let (_, qnet) = quantize_net(&net, &params, &samples, &QuantConfig::default()).unwrap();
        let qnet = Arc::new(qnet);
        let server = Server::start(Arc::clone(&qnet), ServeConfig::default());
        assert!(server.batched(), "quantized affine+relu plans stay batchable");
        let x = NdArray::from_slice(&[1, 4], &[0.2, -0.4, 0.6, -0.8]);
        let got = server.infer(vec![x.clone()]).unwrap();
        let want = qnet.execute_positional(&[x]).unwrap();
        assert_eq!(got[0].data(), want[0].data());
        assert_eq!(server.shutdown().errors, 0);
    }

    #[test]
    fn mean_batch_rows_reflects_microbatching() {
        let plan = affine_plan(&[1., 0., 0., 0., 1., 0.]);
        // one slow-to-fill worker forces queued requests to coalesce
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(200),
            queue_cap: 0,
        };
        let server = Server::start(plan, cfg);
        let rxs: Vec<_> = (0..8)
            .map(|i| server.submit(vec![NdArray::from_slice(&[1, 2], &[i as f32, 0.])]).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0].dims(), &[1, 3]);
            assert_eq!(out[0].data()[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 8);
        // at least some coalescing must have happened with one worker
        // and a 200 ms window
        assert!(stats.batches < 8, "no batching occurred: {stats}");
        assert!(stats.p99_latency_ms >= stats.p50_latency_ms);
    }
}
