//! Batched multi-threaded inference serving on top of compiled plans
//! (ROADMAP north-star: serve heavy traffic as fast as the hardware
//! allows; paper §3.4: one trained NNP file, many runtimes).
//!
//! [`Server`] owns a worker pool sharing one plan behind the
//! [`InferencePlan`] trait — the f32 [`CompiledNet`] or the int8
//! [`crate::quant::QuantizedNet`], compiled once at load time through
//! the full graph-optimizer pipeline (`nnp::passes`, O2: BN folding,
//! no-op elision, dense→ReLU fusion, static memory plan) and
//! executed `&self` from every worker. Single-example requests are
//! **micro-batched**: a worker
//! takes the first queued request, then keeps draining the queue until
//! `max_batch` rows are gathered or `max_wait` elapses, concatenates
//! the inputs along axis 0, executes the plan once, and splits the
//! outputs back per request. Batching is only enabled when the plan is
//! provably row-independent ([`CompiledNet::batch_invariant`]);
//! otherwise every request runs alone — correctness never depends on
//! the batching heuristic, because batched outputs are sliced from the
//! same kernels a solo run would use.
//!
//! The CLI front ends are `nnl serve` (stdin request loop) and
//! `nnl bench-serve` (self-driving throughput benchmark); the
//! compiled-vs-interpreted and batched-vs-unbatched numbers live in
//! `benches/serve_throughput.rs`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::nnp::ir::NetworkDef;
use crate::nnp::plan::{CompiledNet, InferencePlan};
use crate::tensor::{NdArray, Rng};

/// Worker-pool and micro-batching knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads sharing the plan.
    pub workers: usize,
    /// Maximum rows per executed batch (1 disables micro-batching).
    /// A hard cap for coalescing — though a single request carrying
    /// more rows than this still executes, alone.
    pub max_batch: usize,
    /// How long a worker waits for more requests to fill a batch.
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// One queued inference request: positional inputs + reply channel.
struct Request {
    inputs: Vec<NdArray>,
    rows: usize,
    enqueued: Instant,
    reply: Sender<Result<Vec<NdArray>, String>>,
}

/// The shared request queue: a Condvar-guarded deque (not `mpsc`) so a
/// worker parked waiting for work releases the lock while it sleeps —
/// a draining worker can always make progress, and `close()` lets
/// workers finish the backlog and exit even while `Client` handles are
/// still alive.
struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

impl Queue {
    fn new() -> Queue {
        Queue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue, failing cleanly once the server shut down.
    fn push(&self, req: Request) -> Result<(), String> {
        let mut st = self.state.lock().expect("queue lock");
        if st.closed {
            return Err("server shut down".to_string());
        }
        st.items.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    fn pop(&self) -> Option<Request> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            if let Some(r) = st.items.pop_front() {
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).expect("queue lock");
        }
    }

    /// Pop with a deadline, taking the head request only if it fits in
    /// `row_budget` (keeps `max_batch` a hard cap while preserving FIFO
    /// order); `None` on timeout, closed-and-drained, or a head too
    /// large for this batch.
    fn pop_until(&self, deadline: Instant, row_budget: usize) -> Option<Request> {
        let mut st = self.state.lock().expect("queue lock");
        loop {
            if let Some(front) = st.items.front() {
                if front.rows > row_budget {
                    return None; // leave it to start its own batch
                }
                return st.items.pop_front();
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            st = self.cv.wait_timeout(st, deadline - now).expect("queue lock").0;
        }
    }

    /// Stop accepting work and wake every parked worker.
    fn close(&self) {
        self.state.lock().expect("queue lock").closed = true;
        self.cv.notify_all();
    }
}

/// Lock-free counters shared by all workers.
#[derive(Default)]
struct StatsInner {
    requests: AtomicU64,
    rows: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    exec_ns: AtomicU64,
    latency_ns: AtomicU64,
}

/// Snapshot of server throughput/latency counters.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub rows: u64,
    /// Plan executions (each may cover several requests).
    pub batches: u64,
    pub errors: u64,
    pub mean_batch_rows: f64,
    /// Mean wall time inside `CompiledNet::execute` per batch.
    pub mean_exec_ms: f64,
    /// Mean enqueue-to-reply latency per request.
    pub mean_latency_ms: f64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests ({} rows) in {} batches (mean {:.2} rows/batch), \
             mean exec {:.3} ms/batch, mean latency {:.3} ms/request, {} errors",
            self.requests,
            self.rows,
            self.batches,
            self.mean_batch_rows,
            self.mean_exec_ms,
            self.mean_latency_ms,
            self.errors
        )
    }
}

/// A running inference server: worker pool + shared compiled plan.
/// Dropping (or [`Server::shutdown`]) closes the queue, drains pending
/// requests, and joins the workers.
pub struct Server {
    plan: Arc<dyn InferencePlan>,
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<StatsInner>,
    batched: bool,
}

impl Server {
    /// Start `cfg.workers` threads serving `plan` (any
    /// [`InferencePlan`] — the f32 compiled plan or a quantized one).
    pub fn start<P: InferencePlan + 'static>(plan: Arc<P>, cfg: ServeConfig) -> Server {
        Server::start_dyn(plan, cfg)
    }

    /// Type-erased [`Server::start`] — the entry the CLI uses when the
    /// plan's concrete type is only known at run time (`.nnp` vs
    /// NNB/NNB2 artifacts).
    pub fn start_dyn(plan: Arc<dyn InferencePlan>, cfg: ServeConfig) -> Server {
        let queue = Arc::new(Queue::new());
        let stats = Arc::new(StatsInner::default());
        // batching needs provably row-independent semantics
        let batched =
            cfg.max_batch > 1 && !plan.inputs().is_empty() && plan.batch_invariant();
        let n = cfg.workers.max(1);
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let queue = Arc::clone(&queue);
            let plan = Arc::clone(&plan);
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(plan.as_ref(), &queue, &stats, &cfg, batched)
            }));
        }
        Server { plan, queue, workers, stats, batched }
    }

    /// The shared plan.
    pub fn plan(&self) -> &dyn InferencePlan {
        self.plan.as_ref()
    }

    /// Whether micro-batching is active for this plan/config.
    pub fn batched(&self) -> bool {
        self.batched
    }

    /// A cheap cloneable handle for submitting from other threads. A
    /// `Client` does not keep the server alive: after shutdown its
    /// submissions fail cleanly (and workers exit regardless of how
    /// many handles remain).
    pub fn client(&self) -> Client {
        Client {
            plan: Arc::clone(&self.plan),
            queue: Arc::clone(&self.queue),
            batched: self.batched,
        }
    }

    /// Enqueue a request (inputs in declared order; axis 0 free).
    /// Returns the reply channel immediately — shape errors are
    /// rejected here, before they can poison a batch.
    pub fn submit(
        &self,
        inputs: Vec<NdArray>,
    ) -> Result<Receiver<Result<Vec<NdArray>, String>>, String> {
        submit_on(self.plan.as_ref(), self.batched, &self.queue, inputs)
    }

    /// Blocking convenience: submit and wait for the outputs.
    pub fn infer(&self, inputs: Vec<NdArray>) -> Result<Vec<NdArray>, String> {
        let rx = self.submit(inputs)?;
        rx.recv().map_err(|_| "server shut down before replying".to_string())?
    }

    /// Blocking classification: argmax of each row of the first output.
    /// Uses the NaN-safe total ordering shared with trainer validation
    /// ([`crate::tensor::ops::argmax`]) — NaN logits cost accuracy, not
    /// a worker thread.
    pub fn infer_class(&self, inputs: Vec<NdArray>) -> Result<Vec<usize>, String> {
        let out = self.infer(inputs)?;
        let first = out.first().ok_or_else(|| "network has no outputs".to_string())?;
        let rows = first.dims().first().copied().unwrap_or(1).max(1);
        let stride = first.size() / rows;
        if stride == 0 {
            return Err("output rows are empty".to_string());
        }
        Ok((0..rows)
            .map(|r| crate::tensor::ops::argmax(&first.data()[r * stride..(r + 1) * stride]))
            .collect())
    }

    /// Current counters.
    pub fn stats(&self) -> ServeStats {
        let requests = self.stats.requests.load(Ordering::Relaxed);
        let rows = self.stats.rows.load(Ordering::Relaxed);
        let batches = self.stats.batches.load(Ordering::Relaxed);
        let errors = self.stats.errors.load(Ordering::Relaxed);
        let exec_ns = self.stats.exec_ns.load(Ordering::Relaxed);
        let latency_ns = self.stats.latency_ns.load(Ordering::Relaxed);
        ServeStats {
            requests,
            rows,
            batches,
            errors,
            mean_batch_rows: rows as f64 / batches.max(1) as f64,
            mean_exec_ms: exec_ns as f64 / 1e6 / batches.max(1) as f64,
            mean_latency_ms: latency_ns as f64 / 1e6 / requests.max(1) as f64,
        }
    }

    /// Close the queue, finish queued work, join the workers, and
    /// return the final counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A submit-side handle to a running [`Server`]. Clone one per client
/// thread. A `Client` never blocks server shutdown; once the server is
/// gone its submissions fail cleanly.
#[derive(Clone)]
pub struct Client {
    plan: Arc<dyn InferencePlan>,
    queue: Arc<Queue>,
    batched: bool,
}

impl Client {
    /// Same contract as [`Server::submit`].
    pub fn submit(
        &self,
        inputs: Vec<NdArray>,
    ) -> Result<Receiver<Result<Vec<NdArray>, String>>, String> {
        submit_on(self.plan.as_ref(), self.batched, &self.queue, inputs)
    }

    /// Same contract as [`Server::infer`].
    pub fn infer(&self, inputs: Vec<NdArray>) -> Result<Vec<NdArray>, String> {
        let rx = self.submit(inputs)?;
        rx.recv().map_err(|_| "server shut down before replying".to_string())?
    }
}

/// Shared submit path: validate shapes, wrap with a reply channel,
/// enqueue.
fn submit_on(
    plan: &dyn InferencePlan,
    batched: bool,
    queue: &Queue,
    inputs: Vec<NdArray>,
) -> Result<Receiver<Result<Vec<NdArray>, String>>, String> {
    let rows = plan.check_inputs(&inputs)?;
    if batched && !inputs.iter().all(|a| a.dims().first().copied() == Some(rows)) {
        return Err("all inputs of one request must share the batch dimension".to_string());
    }
    let (reply, rx) = channel();
    queue.push(Request { inputs, rows, enqueued: Instant::now(), reply })?;
    Ok(rx)
}

fn worker_loop(
    plan: &dyn InferencePlan,
    queue: &Queue,
    stats: &StatsInner,
    cfg: &ServeConfig,
    batched: bool,
) {
    // pop() parks on the condvar with the lock released, so workers
    // never block each other while idle
    while let Some(first) = queue.pop() {
        let mut batch = vec![first];
        if batched {
            let mut rows = batch[0].rows;
            let deadline = Instant::now() + cfg.max_wait;
            while rows < cfg.max_batch {
                match queue.pop_until(deadline, cfg.max_batch - rows) {
                    Some(r) => {
                        rows += r.rows;
                        batch.push(r);
                    }
                    None => break, // deadline, closed, or next one too big
                }
            }
        }
        run_batch(plan, stats, batch);
    }
}

fn run_batch(plan: &dyn InferencePlan, stats: &StatsInner, mut batch: Vec<Request>) {
    if batch.len() == 1 {
        let req = batch.pop().expect("non-empty batch");
        run_single(plan, stats, req);
        return;
    }
    // concatenate each declared input across requests along axis 0
    let n_inputs = plan.inputs().len();
    let mut cat: Vec<NdArray> = Vec::with_capacity(n_inputs);
    for i in 0..n_inputs {
        let parts: Vec<&NdArray> = batch.iter().map(|r| &r.inputs[i]).collect();
        cat.push(NdArray::concat(&parts, 0));
    }
    let t0 = Instant::now();
    let out = plan.execute_positional(&cat);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    match out {
        Err(e) => {
            stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
            stats.batches.fetch_add(1, Ordering::Relaxed);
            for req in batch {
                finish(stats, req, Err(e.clone()));
            }
        }
        Ok(outs) => {
            let total: usize = batch.iter().map(|r| r.rows).sum();
            if outs.iter().any(|o| o.dims().first().copied() != Some(total)) {
                // batch-invariance heuristic miss: discard the batched
                // run (it is not counted) and answer each request from
                // its own solo execution instead
                for req in batch {
                    run_single(plan, stats, req);
                }
                return;
            }
            stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
            stats.batches.fetch_add(1, Ordering::Relaxed);
            let mut off = 0usize;
            for req in batch {
                let rows = req.rows;
                let slices: Vec<NdArray> =
                    outs.iter().map(|o| o.slice_axis(0, off, off + rows)).collect();
                off += rows;
                finish(stats, req, Ok(slices));
            }
        }
    }
}

fn run_single(plan: &dyn InferencePlan, stats: &StatsInner, req: Request) {
    let t0 = Instant::now();
    let out = plan.execute_positional(&req.inputs);
    stats.exec_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    finish(stats, req, out);
}

/// The serving-throughput harness shared by `nnl bench-serve` and
/// `benches/serve_throughput.rs`: over `requests` random
/// single-example requests, measure per-request interpretation,
/// compiled-sequential execution, and worker-pool serving without and
/// with micro-batching. Returns the rendered report.
pub fn bench_throughput(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    requests: usize,
    cfg: &ServeConfig,
) -> Result<String, String> {
    use crate::utils::bench::{bench, table};
    let plan = Arc::new(CompiledNet::compile(net, params)?);
    let mut rng = Rng::new(7);
    let reqs: Vec<Vec<NdArray>> = (0..requests)
        .map(|_| {
            net.inputs
                .iter()
                .map(|t| {
                    let mut d = t.dims.clone();
                    if !d.is_empty() {
                        d[0] = 1;
                    }
                    rng.rand(&d, -1.0, 1.0)
                })
                .collect()
        })
        .collect();
    let named: Vec<HashMap<String, NdArray>> = reqs
        .iter()
        .map(|r| net.inputs.iter().map(|t| t.name.clone()).zip(r.iter().cloned()).collect())
        .collect();

    // 1. the old deployment path: full interpret (compile) per request
    let interp = bench("interpreter::run per request", 1, 3, || {
        for m in &named {
            crate::nnp::interpreter::run(net, m, params).expect("interpreted run");
        }
    });
    // 2. compile once, execute per request (params bound up front)
    let compiled = bench("compiled plan, sequential", 1, 3, || {
        for r in &reqs {
            plan.execute_positional(r).expect("compiled run");
        }
    });
    // 3./4. worker pool, request-at-a-time vs micro-batched: a load
    // generator submits everything, then awaits every reply
    let drive = |server: &Server| {
        let rxs: Vec<_> =
            reqs.iter().map(|r| server.submit(r.clone()).expect("submit")).collect();
        for rx in rxs {
            rx.recv().expect("server reply").expect("inference ok");
        }
    };
    let workers = cfg.workers.max(1);
    let unbatched =
        Server::start(Arc::clone(&plan), ServeConfig { max_batch: 1, ..cfg.clone() });
    let un_m = bench(&format!("server x{workers}, unbatched"), 1, 3, || drive(&unbatched));
    let batched = Server::start(Arc::clone(&plan), cfg.clone());
    let b_m =
        bench(&format!("server x{workers}, max batch {}", cfg.max_batch), 1, 3, || {
            drive(&batched)
        });

    let rows = vec![interp, compiled, un_m, b_m];
    let mut out =
        table(&format!("Serving throughput: '{}' x {requests} requests", net.name), &rows);
    for r in &rows {
        out.push_str(&format!(
            "  {:<38} {:>10.0} requests/s\n",
            r.name,
            requests as f64 / r.mean_secs
        ));
    }
    out.push_str(&format!("batched server: {}\n", batched.shutdown()));
    out.push_str(&format!("unbatched server: {}\n", unbatched.shutdown()));
    Ok(out)
}

fn finish(stats: &StatsInner, req: Request, out: Result<Vec<NdArray>, String>) {
    if out.is_err() {
        stats.errors.fetch_add(1, Ordering::Relaxed);
    }
    stats.requests.fetch_add(1, Ordering::Relaxed);
    stats.rows.fetch_add(req.rows as u64, Ordering::Relaxed);
    stats.latency_ns.fetch_add(req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
    // the client may have hung up; that is its problem, not ours
    let _ = req.reply.send(out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
    use std::collections::HashMap;

    fn affine_plan(w: &[f32]) -> Arc<CompiledNet> {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "fc".into(),
                op: Op::Affine,
                inputs: vec!["x".into()],
                params: vec!["W".into()],
                outputs: vec!["y".into()],
            }],
        };
        let mut params = HashMap::new();
        params.insert("W".to_string(), NdArray::from_slice(&[2, 3], w));
        Arc::new(CompiledNet::compile(&net, &params).unwrap())
    }

    #[test]
    fn serves_requests_and_matches_direct_execution() {
        let plan = affine_plan(&[1., 2., 3., 4., 5., 6.]);
        let server = Server::start(Arc::clone(&plan), ServeConfig::default());
        assert!(server.batched());
        let mut handles = Vec::new();
        for i in 0..16 {
            let x = NdArray::from_slice(&[1, 2], &[i as f32, -(i as f32)]);
            handles.push((x.clone(), server.submit(vec![x]).unwrap()));
        }
        for (x, rx) in handles {
            let got = rx.recv().unwrap().unwrap();
            let want = plan.execute_positional(&[x]).unwrap();
            assert_eq!(got[0].dims(), want[0].dims());
            assert_eq!(got[0].data(), want[0].data());
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 16);
        assert_eq!(stats.rows, 16);
        assert!(stats.batches <= 16);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn non_batchable_plan_served_per_request() {
        let net = NetworkDef {
            name: "sum".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "s".into(),
                op: Op::SumAll,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = Arc::new(CompiledNet::compile(&net, &HashMap::new()).unwrap());
        let server = Server::start(Arc::clone(&plan), ServeConfig::default());
        assert!(!server.batched());
        let out = server.infer(vec![NdArray::from_slice(&[1, 2], &[3., 4.])]).unwrap();
        assert_eq!(out[0].data(), &[7.]);
    }

    #[test]
    fn bad_shapes_rejected_at_submit() {
        let plan = affine_plan(&[1., 2., 3., 4., 5., 6.]);
        let server = Server::start(plan, ServeConfig::default());
        let err = server.submit(vec![NdArray::zeros(&[2])]).unwrap_err();
        assert!(err.contains("incompatible"), "{err}");
        let err = server.submit(vec![]).unwrap_err();
        assert!(err.contains("expects 1 inputs"), "{err}");
    }

    #[test]
    fn nan_logits_classify_without_panicking() {
        // second class scores NaN for every input; prediction must fall
        // back to the best finite logit instead of killing a worker
        let plan = affine_plan(&[1., f32::NAN, 0., 1., f32::NAN, 0.]);
        let server = Server::start(plan, ServeConfig::default());
        let classes =
            server.infer_class(vec![NdArray::from_slice(&[2, 2], &[5., 1., 0., 2.])]).unwrap();
        assert_eq!(classes, vec![0, 0]);
    }

    #[test]
    fn server_hosts_quantized_plans() {
        use crate::quant::{quantize_net, QuantConfig};
        use crate::tensor::Rng;
        let net = NetworkDef {
            name: "q".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut rng = Rng::new(31);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[4, 3], 1.0));
        let samples: Vec<Vec<NdArray>> =
            (0..4).map(|_| vec![rng.rand(&[1, 4], -1.0, 1.0)]).collect();
        let (_, qnet) =
            quantize_net(&net, &params, &samples, &QuantConfig::default()).unwrap();
        let qnet = Arc::new(qnet);
        let server = Server::start(Arc::clone(&qnet), ServeConfig::default());
        assert!(server.batched(), "quantized affine+relu plans stay batchable");
        let x = NdArray::from_slice(&[1, 4], &[0.2, -0.4, 0.6, -0.8]);
        let got = server.infer(vec![x.clone()]).unwrap();
        let want = qnet.execute_positional(&[x]).unwrap();
        assert_eq!(got[0].data(), want[0].data());
        assert_eq!(server.shutdown().errors, 0);
    }

    #[test]
    fn mean_batch_rows_reflects_microbatching() {
        let plan = affine_plan(&[1., 0., 0., 0., 1., 0.]);
        // one slow-to-fill worker forces queued requests to coalesce
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(200),
        };
        let server = Server::start(plan, cfg);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                server
                    .submit(vec![NdArray::from_slice(&[1, 2], &[i as f32, 0.])])
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0].dims(), &[1, 3]);
            assert_eq!(out[0].data()[0], i as f32);
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 8);
        // at least some coalescing must have happened with one worker
        // and a 200 ms window
        assert!(stats.batches < 8, "no batching occurred: {stats}");
    }
}
