//! Storage dtypes. Compute is always f32; `BF16`/`F16` tag arrays whose
//! values are quantized to half precision on write (paper §3.3:
//! "storage (weights, activations, gradients) is performed in FP-16").

use crate::utils::half;

/// Storage precision of an [`crate::tensor::NdArray`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// IEEE-754 single precision (the default `float` type_config).
    F32,
    /// bfloat16 storage (the `half` type_config on TPU-like hardware).
    BF16,
    /// IEEE-754 half storage (the `half` type_config on Volta-like hardware).
    F16,
}

impl DType {
    /// Round `v` to the nearest value representable in this dtype.
    #[inline]
    pub fn quantize(self, v: f32) -> f32 {
        match self {
            DType::F32 => v,
            DType::BF16 => half::bf16_round(v),
            DType::F16 => half::f16_round(v),
        }
    }

    /// Bytes per element when serialized.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }

    /// Name used by the NNP text format and the artifact manifest.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::BF16 => "bfloat16",
            DType::F16 => "float16",
        }
    }

    /// Parse a dtype name (manifest / nntxt).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "bfloat16" | "bf16" => Some(DType::BF16),
            "float16" | "f16" => Some(DType::F16),
            _ => None,
        }
    }

    /// Largest finite value representable (used by the loss-scaler and
    /// overflow detection in half simulation).
    pub fn max_finite(self) -> f32 {
        match self {
            DType::F32 => f32::MAX,
            DType::BF16 => half::BF16_MAX,
            DType::F16 => half::F16_MAX,
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_quantize_is_identity() {
        for v in [0.0f32, 1.5, -3.25e7, f32::MIN_POSITIVE] {
            assert_eq!(DType::F32.quantize(v), v);
        }
    }

    #[test]
    fn bf16_quantize_truncates_mantissa() {
        // bf16 has 8 mantissa bits; 1 + 2^-9 is not representable.
        let v = 1.0 + 2f32.powi(-9);
        let q = DType::BF16.quantize(v);
        assert_ne!(q, v);
        assert!((q - v).abs() < 2f32.powi(-8));
    }

    #[test]
    fn f16_overflows_to_inf() {
        // 70000 > f16::MAX (65504) — overflow behaviour the dynamic
        // loss scaler must detect.
        assert!(DType::F16.quantize(70_000.0).is_infinite());
        assert!(DType::BF16.quantize(70_000.0).is_finite());
    }

    #[test]
    fn names_round_trip() {
        for d in [DType::F32, DType::BF16, DType::F16] {
            assert_eq!(DType::from_name(d.name()), Some(d));
        }
        assert_eq!(DType::from_name("int8"), None);
    }

    #[test]
    fn size_of_matches_spec() {
        assert_eq!(DType::F32.size_of(), 4);
        assert_eq!(DType::BF16.size_of(), 2);
        assert_eq!(DType::F16.size_of(), 2);
    }
}
