//! Tiled, multi-threaded compute kernels + the per-thread scratch
//! arena — the CPU answer to the paper's "speedy computation" pillar.
//!
//! ## One GEMM core, many operand views
//!
//! Every dense hot-path product in the framework (affine forward and
//! both its gradients, conv/deconv forward and all their gradients)
//! is a GEMM whose operands are *views*: a plain row-major matrix, a
//! transposed one, an NCHW tensor read as `[n·h·w, c]` rows, or the
//! im2col matrix of an image. [`Mat`] names those views and the tiled
//! core packs panels straight out of them — so convolution never
//! materializes its column matrix at all: im2col happens inside the
//! pack step, one register tile at a time, and the full `[n·oh·ow,
//! c·kh·kw]` buffer that the old lowering allocated per call simply
//! does not exist.
//!
//! The core itself is the classic register-tiled shape: pack a
//! `KC×NR` B-panel per column tile and a `KC×MR` A-panel per row
//! tile, then an unrolled `MR×NR` (8×8) microkernel accumulates into
//! registers — cache-blocked over k. Row tiles are sharded across
//! [`crate::tensor::parallel`]'s worker pool; each output element is
//! produced by exactly one chunk with a fixed k-ascending accumulation
//! order, so results are bit-identical at any `NNL_THREADS` (the
//! pool's determinism contract).
//!
//! ## SIMD tiers
//!
//! The microkernel (and the fused bias/ReLU/requantize epilogues) come
//! in hand-written `std::arch` variants — AVX2+FMA on x86_64, NEON on
//! aarch64 — selected once per process by [`dispatch`]
//! (`is_x86_feature_detected!`, overridable via `NNL_ISA`). The scalar
//! kernels stay as the always-available parity oracle. A GEMM resolves
//! its tier once at entry and carries it into every pool chunk, so
//! per-ISA bit-identity across thread counts is preserved; products
//! below the small-GEMM cutoff run the same scalar loop at every tier.
//! Panel buffers are carved 32-byte-aligned out of the scratch arena
//! ([`Scratch::take_panel`]) so vector loads hit full-speed paths —
//! alignment is perf-only, the kernels use unaligned intrinsics.
//!
//! ## The scratch arena
//!
//! [`Scratch`] is a per-thread pool of `Vec<f32>` buffers. Kernels
//! borrow it for packed panels and intermediates, and the compiled-plan
//! executor ([`crate::nnp::plan::CompiledNet`]) recycles freed
//! activation slots back into it ([`recycle`]) — after the first
//! request, a serving thread's steady state performs no heap
//! allocation for conv columns or plan intermediates. [`with_scratch`]
//! is reentrancy-safe: nested scopes take the arena by value and merge
//! buffers back on exit.

#![allow(clippy::too_many_arguments)]

pub mod dispatch;
pub mod int8;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::cell::RefCell;

use dispatch::Isa;

use super::ops::{self, Conv2dGeom};
use super::parallel;
use super::NdArray;

/// Microkernel rows (output tile height).
const MR: usize = 8;
/// Microkernel cols (output tile width).
const NR: usize = 8;
/// k-dimension cache block: panels of KC stay L1/L2-resident.
const KC: usize = 256;
/// Below this many multiply-adds the packed path costs more than it
/// saves; run the plain blocked loop instead (serial — these are the
/// tape's many tiny matmuls).
const SMALL_FLOPS: usize = 32 * 32 * 32;
/// Cap on row chunks per GEMM: bounds claim overhead while keeping the
/// partition a pure function of the problem shape (determinism).
const MAX_CHUNKS: usize = 64;

// ------------------------------------------------------------------ scratch

/// Extra f32 lanes that guarantee a 32-byte-aligned window of any
/// requested length can be carved out of a `Vec<f32>` allocation
/// (worst case the vec starts 4 bytes past a boundary: 7 lanes skip).
const ALIGN_PAD: usize = 7;

/// Lanes to skip so `p.add(offset)` sits on a 32-byte boundary.
/// Computed from the address bits directly — `<*const T>::align_offset`
/// is documented as allowed to spuriously return `usize::MAX`, which
/// would turn a perf nicety into a panic.
fn align32_offset(p: *const f32) -> usize {
    let mis = p as usize & 31;
    if mis == 0 {
        0
    } else {
        // Vec<f32> is at least 4-aligned, so `mis` is a multiple of 4
        (32 - mis) / 4
    }
}

/// A scratch buffer whose live window starts on a 32-byte boundary —
/// what the AVX2/NEON panel loads want. Alignment here is purely a
/// performance property: the SIMD microkernels use unaligned
/// load/store intrinsics throughout, so a hostile offset could at
/// worst be slow, never unsound.
pub struct Panel {
    buf: Vec<f32>,
    off: usize,
    len: usize,
}

impl Panel {
    /// The aligned window (contents unspecified until written).
    pub fn slice(&self) -> &[f32] {
        &self.buf[self.off..self.off + self.len]
    }

    /// The aligned window, mutably.
    pub fn slice_mut(&mut self) -> &mut [f32] {
        &mut self.buf[self.off..self.off + self.len]
    }
}

/// A pool of reusable `f32` buffers. One lives per thread (see
/// [`with_scratch`]); long-lived executors return dead intermediates to
/// it so steady-state inference allocates nothing.
#[derive(Default)]
pub struct Scratch {
    bufs: Vec<Vec<f32>>,
}

impl Scratch {
    /// Buffers kept beyond this are dropped (bounds worst-case memory).
    const MAX_BUFS: usize = 24;

    pub fn new() -> Self {
        Scratch::default()
    }

    /// A zero-filled buffer of exactly `len` (for accumulation
    /// targets like col2im). Reuses pooled capacity like
    /// [`Scratch::take_uninit`].
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_uninit(len);
        v.fill(0.0);
        v
    }

    /// A buffer of exactly `len` with **unspecified contents** (reused
    /// allocations keep stale values — no memset). For outputs whose
    /// every element is written before being read: GEMM destinations,
    /// pack panels, layout transposes. Picks the smallest pooled
    /// buffer that fits, else grows the largest one.
    pub fn take_uninit(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.bufs.iter().enumerate() {
            best = Some(match best {
                None => i,
                Some(j) => {
                    let (ic, jc) = (b.capacity(), self.bufs[j].capacity());
                    let better = if jc >= len { ic >= len && ic < jc } else { ic > jc };
                    if better {
                        i
                    } else {
                        j
                    }
                }
            });
        }
        let mut v = match best {
            Some(i) => self.bufs.swap_remove(i),
            None => Vec::new(),
        };
        if v.len() >= len {
            v.truncate(len);
        } else {
            v.resize(len, 0.0);
        }
        v
    }

    /// A [`Panel`]: `len` f32 of unspecified contents whose window is
    /// 32-byte aligned (over-allocates by [`ALIGN_PAD`] and skips to
    /// the first boundary). For packed GEMM panels the vector kernels
    /// stream through.
    pub fn take_panel(&mut self, len: usize) -> Panel {
        let buf = self.take_uninit(len + ALIGN_PAD);
        let off = align32_offset(buf.as_ptr());
        Panel { buf, off, len }
    }

    /// Return a panel's buffer to the pool.
    pub fn put_panel(&mut self, p: Panel) {
        self.put(p.buf);
    }

    /// Return a buffer to the pool.
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.bufs.len() < Self::MAX_BUFS {
            self.bufs.push(v);
        }
    }

    fn absorb(&mut self, mut other: Scratch) {
        for b in other.bufs.drain(..) {
            self.put(b);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
    /// Tiny per-thread A-panel pack buffer (distinct from SCRATCH so a
    /// pool chunk can pack while its submitter holds the main arena).
    static PACK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// A 32-byte-aligned `len`-f32 window into a pack buffer, growing it
/// as needed — the thread-local twin of [`Scratch::take_panel`] (same
/// perf-only alignment story).
fn aligned_pack(v: &mut Vec<f32>, len: usize) -> &mut [f32] {
    let need = len + ALIGN_PAD;
    if v.len() < need {
        v.resize(need, 0.0);
    }
    let off = align32_offset(v.as_ptr());
    &mut v[off..off + len]
}

/// Run `f` with this thread's scratch arena. Reentrancy-safe: a nested
/// scope sees an empty arena and its buffers merge back on exit, so no
/// `RefCell` borrow is ever held across user code.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    let mut s = SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
    let r = f(&mut s);
    SCRATCH.with(|c| c.borrow_mut().absorb(s));
    r
}

/// Drop every pooled buffer on this thread — post-panic hygiene for
/// supervised serve workers. A request that unwound mid-kernel left
/// `with_scratch`'s taken arena to be dropped (so those buffers are
/// already gone); this clears what the thread-local still holds so a
/// resurrected worker starts from a provably clean arena instead of
/// one whose reuse story depends on where exactly the unwind happened.
/// Safe to call any time: `with_scratch` never holds a `RefCell`
/// borrow across user code, so no borrow can be live here.
pub fn purge_scratch() {
    SCRATCH.with(|c| c.borrow_mut().bufs.clear());
    PACK.with(|p| *p.borrow_mut() = Vec::new());
}

/// Return a dead array's buffer to this thread's arena (no-op if the
/// storage is still shared). The compiled-plan executor feeds freed
/// activation slots through this, closing the allocate/free loop.
pub fn recycle(a: NdArray) {
    if let Some(v) = a.into_unique_vec() {
        if v.capacity() > 0 {
            SCRATCH.with(|c| c.borrow_mut().put(v));
        }
    }
}

// ------------------------------------------------------------ operand views

/// im2col-of-an-image view: logical shape `[n·oh·ow, c·kh·kw]`.
#[derive(Clone, Copy)]
struct ColView<'a> {
    x: &'a [f32],
    c: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    g: Conv2dGeom,
}

impl ColView<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        let ohow = self.oh * self.ow;
        let ni = i / ohow;
        let rem = i % ohow;
        let oy = rem / self.ow;
        let ox = rem % self.ow;
        let (kh, kw) = self.g.kernel;
        let khkw = kh * kw;
        let ci = j / khkw;
        let r = j % khkw;
        let ky = r / kw;
        let kx = r % kw;
        let iy = (oy * self.g.stride.0 + ky * self.g.dilation.0) as isize - self.g.pad.0 as isize;
        let ix = (ox * self.g.stride.1 + kx * self.g.dilation.1) as isize - self.g.pad.1 as isize;
        if iy >= 0 && (iy as usize) < self.h && ix >= 0 && (ix as usize) < self.w {
            self.x[((ni * self.c + ci) * self.h + iy as usize) * self.w + ix as usize]
        } else {
            0.0
        }
    }
}

/// NCHW tensor read as rows `[n·h·w, c]` (the `transpose(0,2,3,1)`
/// flatten, without materializing it).
#[derive(Clone, Copy)]
struct NhwcView<'a> {
    x: &'a [f32],
    c: usize,
    hw: usize,
}

impl NhwcView<'_> {
    fn of(x: &NdArray) -> NhwcView<'_> {
        assert_eq!(x.rank(), 4, "NHWC view expects an NCHW tensor");
        NhwcView { x: x.data(), c: x.dims()[1], hw: x.dims()[2] * x.dims()[3] }
    }

    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        let ni = i / self.hw;
        let rem = i % self.hw;
        self.x[(ni * self.c + j) * self.hw + rem]
    }
}

/// A GEMM operand: a way to read element `[i, j]` of a logical matrix.
/// The tiled core only touches operands through panel packing, so a
/// view costs its index math once per packed element — O(m·k + k·n)
/// against the O(m·k·n) multiply work it feeds.
enum Mat<'a> {
    /// Row-major `[rows, cols]`; `ld` = cols.
    Dense { d: &'a [f32], ld: usize },
    /// Logical transpose of a row-major matrix; `ld` = its row length
    /// (= logical rows).
    DenseT { d: &'a [f32], ld: usize },
    /// im2col of an NCHW image.
    Im2col(ColView<'a>),
    /// NCHW as `[n·h·w, c]` rows.
    Nhwc(NhwcView<'a>),
    /// Transpose of [`Mat::Nhwc`]: `[c, n·h·w]`.
    NhwcT(NhwcView<'a>),
}

impl Mat<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        match self {
            Mat::Dense { d, ld } => d[i * ld + j],
            Mat::DenseT { d, ld } => d[j * ld + i],
            Mat::Im2col(v) => v.at(i, j),
            Mat::Nhwc(v) => v.at(i, j),
            Mat::NhwcT(v) => v.at(j, i),
        }
    }

    /// Materialize `[rows, cols]` into `buf` (small-GEMM fallback).
    fn fill_dense(&self, buf: &mut [f32], rows: usize, cols: usize) {
        debug_assert_eq!(buf.len(), rows * cols);
        if let Mat::Dense { d, ld } = self {
            if *ld == cols {
                buf.copy_from_slice(&d[..rows * cols]);
                return;
            }
        }
        for i in 0..rows {
            let row = &mut buf[i * cols..(i + 1) * cols];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = self.at(i, j);
            }
        }
    }
}

// ------------------------------------------------------------------- GEMM

/// `out[m,n] = A[m,k] · B[k,n]`, any operand views. Dispatches to the
/// serial blocked loop for small products and the packed, row-sharded
/// tiled core otherwise; the cutoff depends only on the shape, so a
/// given logical product always takes the same path (bit-identical
/// results however the operands are expressed).
fn gemm_any(out: &mut [f32], a: &Mat, b: &Mat, m: usize, k: usize, n: usize, s: &mut Scratch) {
    assert_eq!(out.len(), m * n, "gemm output buffer size");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    if m.saturating_mul(k).saturating_mul(n) <= SMALL_FLOPS {
        gemm_small(out, a, b, m, k, n, s);
    } else {
        gemm_tiled(out, a, b, m, k, n, s);
    }
}

/// Small-product path: the pre-tiling blocked i-k-j loop on dense
/// slices (virtual operands are first materialized from scratch —
/// cheap at these sizes, and it keeps the inner loop streaming).
fn gemm_small(out: &mut [f32], a: &Mat, b: &Mat, m: usize, k: usize, n: usize, s: &mut Scratch) {
    let mut abuf = Vec::new();
    let ad: &[f32] = match a {
        Mat::Dense { d, ld } if *ld == k => &d[..m * k],
        _ => {
            abuf = s.take_uninit(m * k);
            a.fill_dense(&mut abuf, m, k);
            &abuf
        }
    };
    let mut bbuf = Vec::new();
    let bd: &[f32] = match b {
        Mat::Dense { d, ld } if *ld == n => &d[..k * n],
        _ => {
            bbuf = s.take_uninit(k * n);
            b.fill_dense(&mut bbuf, k, n);
            &bbuf
        }
    };
    const KB: usize = 64;
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        orow.fill(0.0);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KB).min(k);
            for kk in k0..k1 {
                let av = arow[kk];
                let brow = &bd[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            k0 = k1;
        }
    }
    s.put(abuf);
    s.put(bbuf);
}

/// Pack the `MR`-row A-panel for rows `i0..` over `k0..k0+kc`:
/// `ap[kk·MR + r] = A[i0+r, k0+kk]`, zero-padded past `m`.
fn pack_a_panel(a: &Mat, ap: &mut [f32], m: usize, i0: usize, k0: usize, kc: usize) {
    let mh = MR.min(m - i0);
    for kk in 0..kc {
        let col = k0 + kk;
        let dst = &mut ap[kk * MR..kk * MR + MR];
        for (r, slot) in dst.iter_mut().enumerate().take(mh) {
            *slot = a.at(i0 + r, col);
        }
        for slot in dst.iter_mut().skip(mh) {
            *slot = 0.0;
        }
    }
}

/// Pack the `NR`-col B-panel for cols `j0..` over `k0..k0+kc`:
/// `bp[kk·NR + c] = B[k0+kk, j0+c]`, zero-padded past `n`.
fn pack_b_panel(b: &Mat, bp: &mut [f32], n: usize, j0: usize, k0: usize, kc: usize) {
    let nw = NR.min(n - j0);
    for kk in 0..kc {
        let row = k0 + kk;
        let dst = &mut bp[kk * NR..kk * NR + NR];
        for (c, slot) in dst.iter_mut().enumerate().take(nw) {
            *slot = b.at(row, j0 + c);
        }
        for slot in dst.iter_mut().skip(nw) {
            *slot = 0.0;
        }
    }
}

/// The register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]` with
/// fixed 8×8 unrolled inner loops (LLVM vectorizes the `c` loop and
/// keeps `acc` in registers).
#[inline(always)]
fn microkernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    for kk in 0..kc {
        let a = &ap[kk * MR..kk * MR + MR];
        let b = &bp[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = a[r];
            let row = &mut acc[r * NR..r * NR + NR];
            for (o, &bv) in row.iter_mut().zip(b) {
                *o += ar * bv;
            }
        }
    }
}

/// Run the `MR×NR` register tile on the given tier. The scalar kernel
/// is the shared parity oracle; the vector variants only ever run for
/// an [`Isa`] that [`dispatch`] proved executable.
#[inline]
fn run_microkernel(isa: Isa, kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx2` is only ever produced by `dispatch` after
        // `is_x86_feature_detected!` proves avx2+fma (env override and
        // `with_isa` both validate through the same check), and the
        // slice-length contract is the scalar kernel's own.
        Isa::Avx2 => unsafe { x86::microkernel_f32(kc, ap, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Isa::Neon` only exists on aarch64, where NEON is an
        // architectural baseline; slice lengths per the shared contract.
        Isa::Neon => unsafe { neon::microkernel_f32(kc, ap, bp, acc) },
        _ => microkernel(kc, ap, bp, acc),
    }
}

/// Packed, k-blocked, row-sharded tiled GEMM. Per k-block: B-panels are
/// packed once (shared, read-only, 32-byte aligned), then row-tile
/// chunks run on the pool, each packing its own A-panels into the
/// per-thread [`PACK`] buffer. The first k-block overwrites `out`,
/// later ones accumulate. The ISA tier is resolved once here on the
/// submitting thread and carried into every chunk as plain data — one
/// GEMM never mixes tiers, whatever threads it lands on.
fn gemm_tiled(out: &mut [f32], a: &Mat, b: &Mat, m: usize, k: usize, n: usize, s: &mut Scratch) {
    let isa = dispatch::isa();
    let n_itiles = m.div_ceil(MR);
    let n_jtiles = n.div_ceil(NR);
    let chunk_tiles = n_itiles.div_ceil(MAX_CHUNKS).max(1);
    let chunk_elems = chunk_tiles * MR * n;
    let mut k0 = 0;
    while k0 < k {
        let kc = KC.min(k - k0);
        let mut bp_panel = s.take_panel(n_jtiles * kc * NR);
        {
            let bp_all = bp_panel.slice_mut();
            for jt in 0..n_jtiles {
                pack_b_panel(b, &mut bp_all[jt * kc * NR..(jt + 1) * kc * NR], n, jt * NR, k0, kc);
            }
        }
        let first = k0 == 0;
        let bp_all_ref = bp_panel.slice();
        parallel::for_each_chunk_mut(out, chunk_elems, |ci, chunk| {
            PACK.with(|p| {
                let mut pack = p.borrow_mut();
                let ap = aligned_pack(&mut pack, kc * MR);
                debug_assert_eq!(chunk.len() % n, 0);
                let rows_here = chunk.len() / n;
                let row_base = ci * chunk_tiles * MR;
                let mut local0 = 0;
                while local0 < rows_here {
                    let i0 = row_base + local0;
                    let mh = MR.min(rows_here - local0);
                    pack_a_panel(a, ap, m, i0, k0, kc);
                    for jt in 0..n_jtiles {
                        let j0 = jt * NR;
                        let nw = NR.min(n - j0);
                        let bp = &bp_all_ref[jt * kc * NR..(jt + 1) * kc * NR];
                        let mut acc = [0.0f32; MR * NR];
                        run_microkernel(isa, kc, ap, bp, &mut acc);
                        for r in 0..mh {
                            let dst = &mut chunk[(local0 + r) * n + j0..(local0 + r) * n + j0 + nw];
                            let src = &acc[r * NR..r * NR + nw];
                            if first {
                                dst.copy_from_slice(src);
                            } else {
                                for (d, &v) in dst.iter_mut().zip(src) {
                                    *d += v;
                                }
                            }
                        }
                    }
                    local0 += MR;
                }
            });
        });
        s.put_panel(bp_panel);
        k0 += kc;
    }
}

/// Dense row-major `out[m,n] = a[m,k] · b[k,n]` — the public entry the
/// tensor-level [`ops::matmul`] rides on.
pub fn matmul_into(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    s: &mut Scratch,
) {
    assert_eq!(a.len(), m * k, "gemm lhs size");
    assert_eq!(b.len(), k * n, "gemm rhs size");
    gemm_any(out, &Mat::Dense { d: a, ld: k }, &Mat::Dense { d: b, ld: n }, m, k, n, s);
}

// ----------------------------------------------------------------- affine

/// `y = flatten(x) · W (+ b)` — shared by the tape's `F::affine`
/// forward and the compiled plan's fast path, so the two are
/// bit-identical by construction.
pub fn affine_forward(x: &NdArray, w: &NdArray, bias: Option<&NdArray>) -> NdArray {
    assert!(x.rank() >= 1, "affine input must have a batch axis");
    assert_eq!(w.rank(), 2, "affine weight must be rank 2");
    let batch = x.dims()[0];
    let feat: usize = x.dims()[1..].iter().product();
    let (inf, outf) = (w.dims()[0], w.dims()[1]);
    assert_eq!(feat, inf, "affine input features {feat} vs weight rows {inf}");
    with_scratch(|s| {
        let mut out = s.take_uninit(batch * outf);
        gemm_any(
            &mut out,
            &Mat::Dense { d: x.data(), ld: feat },
            &Mat::Dense { d: w.data(), ld: outf },
            batch,
            inf,
            outf,
            s,
        );
        if let Some(bv) = bias {
            add_bias_rows(&mut out, bv.data(), outf);
        }
        NdArray::from_vec(&[batch, outf], out)
    })
}

/// Affine gradients `(gx, gw, gb)` — `gx = gy·Wᵀ`, `gw = xᵀ·gy`,
/// `gb = Σ_batch gy` — with both transposes taken as views (nothing is
/// materialized).
pub fn affine_backward(
    x: &NdArray,
    w: &NdArray,
    gy: &NdArray,
    has_bias: bool,
) -> (NdArray, NdArray, Option<NdArray>) {
    let batch = x.dims()[0];
    let feat: usize = x.dims()[1..].iter().product();
    let outf = w.dims()[1];
    assert_eq!(gy.size(), batch * outf, "affine grad shape");
    with_scratch(|s| {
        let mut gx = s.take_uninit(batch * feat);
        gemm_any(
            &mut gx,
            &Mat::Dense { d: gy.data(), ld: outf },
            &Mat::DenseT { d: w.data(), ld: outf },
            batch,
            outf,
            feat,
            s,
        );
        let mut gw = s.take_uninit(feat * outf);
        gemm_any(
            &mut gw,
            &Mat::DenseT { d: x.data(), ld: feat },
            &Mat::Dense { d: gy.data(), ld: outf },
            feat,
            batch,
            outf,
            s,
        );
        let gb = has_bias.then(|| ops::sum_axis(gy, 0, false));
        (
            NdArray::from_vec(x.dims(), gx),
            NdArray::from_vec(w.dims(), gw),
            gb,
        )
    })
}

// ------------------------------------------------------------- convolution

fn conv_dims(x: &NdArray, w: &NdArray, g: &Conv2dGeom) -> (usize, usize, usize, usize, usize) {
    assert_eq!(x.rank(), 4, "conv2d expects NCHW input");
    assert_eq!(w.rank(), 4, "conv2d expects OIHW weights");
    assert_eq!(
        w.dims()[1],
        x.dims()[1],
        "conv2d weight in-channels {} vs input channels {}",
        w.dims()[1],
        x.dims()[1]
    );
    assert_eq!(g.kernel, (w.dims()[2], w.dims()[3]), "conv2d geometry kernel vs weight shape");
    let (n, h, wd) = (x.dims()[0], x.dims()[2], x.dims()[3]);
    let (oh, ow) = g.out_hw(h, wd);
    (n, w.dims()[0], oh, ow, x.dims()[1] * w.dims()[2] * w.dims()[3])
}

/// Fused conv forward `y = conv(x, W) (+ b)`, NCHW out. The im2col
/// matrix is only ever realized as transient `KC×8` pack panels.
pub fn conv2d_forward(
    x: &NdArray,
    w: &NdArray,
    bias: Option<&NdArray>,
    g: &Conv2dGeom,
) -> NdArray {
    let (n, oc, oh, ow, ckk) = conv_dims(x, w, g);
    let rows = n * oh * ow;
    with_scratch(|s| {
        let cols = ColView {
            x: x.data(),
            c: x.dims()[1],
            h: x.dims()[2],
            w: x.dims()[3],
            oh,
            ow,
            g: *g,
        };
        let mut yrows = s.take_uninit(rows * oc);
        // cols [rows, ckk] · Wᵀ [ckk, oc]
        gemm_any(
            &mut yrows,
            &Mat::Im2col(cols),
            &Mat::DenseT { d: w.data(), ld: ckk },
            rows,
            ckk,
            oc,
            s,
        );
        if let Some(bv) = bias {
            assert_eq!(bv.size(), oc, "conv bias size");
            add_bias_rows(&mut yrows, bv.data(), oc);
        }
        let mut out = s.take_uninit(rows * oc);
        nhwc_to_nchw(&mut out, &yrows, n, oc, oh, ow);
        s.put(yrows);
        NdArray::from_vec(&[n, oc, oh, ow], out)
    })
}

/// Conv gradients `(gx, gw, gb)`: `gx = col2im(gy_rows · W)`,
/// `gw = gy_rowsᵀ · im2col(x)`, `gb` = per-channel sums — all operands
/// taken as views, nothing materialized but the outputs.
pub fn conv2d_backward(
    x: &NdArray,
    w: &NdArray,
    gy: &NdArray,
    has_bias: bool,
    g: &Conv2dGeom,
) -> (NdArray, NdArray, Option<NdArray>) {
    let (n, oc, oh, ow, ckk) = conv_dims(x, w, g);
    assert_eq!(gy.dims(), &[n, oc, oh, ow], "conv grad shape");
    let rows = n * oh * ow;
    with_scratch(|s| {
        let gyr = NhwcView::of(gy); // [rows, oc]
        let mut gcols = s.take_uninit(rows * ckk);
        gemm_any(
            &mut gcols,
            &Mat::Nhwc(gyr),
            &Mat::Dense { d: w.data(), ld: ckk },
            rows,
            oc,
            ckk,
            s,
        );
        let mut gx = s.take(x.size());
        ops::col2im_slice(&mut gx, &gcols, x.dims(), g);
        s.put(gcols);
        let cols = ColView {
            x: x.data(),
            c: x.dims()[1],
            h: x.dims()[2],
            w: x.dims()[3],
            oh,
            ow,
            g: *g,
        };
        let mut gw = s.take_uninit(oc * ckk);
        gemm_any(&mut gw, &Mat::NhwcT(gyr), &Mat::Im2col(cols), oc, rows, ckk, s);
        let gb = has_bias.then(|| channel_sums(gy));
        (
            NdArray::from_vec(x.dims(), gx),
            NdArray::from_vec(w.dims(), gw),
            gb,
        )
    })
}

// ----------------------------------------------------------- deconvolution

fn deconv_out_hw(
    h: usize,
    w: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
) -> (usize, usize) {
    let oh = ((h - 1) * stride.0 + kernel.0)
        .checked_sub(2 * pad.0)
        .filter(|&v| v > 0)
        .unwrap_or_else(|| panic!("deconvolution geometry invalid: pad {pad:?} swallows output"));
    let ow = ((w - 1) * stride.1 + kernel.1)
        .checked_sub(2 * pad.1)
        .filter(|&v| v > 0)
        .unwrap_or_else(|| panic!("deconvolution geometry invalid: pad {pad:?} swallows output"));
    (oh, ow)
}

/// Deconv forward: `y = col2im(x_rows · W)` — conv's adjoint spatial
/// map. `x: [N,C,H,W]`, `w: [C,OC,KH,KW]`.
pub fn deconv2d_forward(
    x: &NdArray,
    w: &NdArray,
    bias: Option<&NdArray>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> NdArray {
    assert_eq!(x.rank(), 4, "deconv expects NCHW input");
    assert_eq!(w.rank(), 4, "deconv expects IOHW weights");
    let (n, c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    assert_eq!(w.dims()[0], c, "deconv weight in-channels");
    let (oc, kh, kw) = (w.dims()[1], w.dims()[2], w.dims()[3]);
    let (oh, ow) = deconv_out_hw(h, wd, (kh, kw), stride, pad);
    let geom = Conv2dGeom { kernel: (kh, kw), stride, pad, dilation: (1, 1) };
    let rows = n * h * wd;
    let ockk = oc * kh * kw;
    with_scratch(|s| {
        let mut cols = s.take_uninit(rows * ockk);
        // x_rows [rows, c] · W [c, oc·kh·kw]
        gemm_any(
            &mut cols,
            &Mat::Nhwc(NhwcView::of(x)),
            &Mat::Dense { d: w.data(), ld: ockk },
            rows,
            c,
            ockk,
            s,
        );
        let out_dims = [n, oc, oh, ow];
        let mut out = s.take(n * oc * oh * ow);
        ops::col2im_slice(&mut out, &cols, &out_dims, &geom);
        s.put(cols);
        if let Some(bv) = bias {
            assert_eq!(bv.size(), oc, "deconv bias size");
            add_bias_planes(&mut out, bv.data(), n, oc, oh * ow);
        }
        NdArray::from_vec(&out_dims, out)
    })
}

/// Deconv gradients `(gx, gw, gb)`: `gx = im2col(gy) · Wᵀ` back to
/// NCHW, `gw = x_rowsᵀ · im2col(gy)`, `gb` = per-channel sums.
pub fn deconv2d_backward(
    x: &NdArray,
    w: &NdArray,
    gy: &NdArray,
    has_bias: bool,
    stride: (usize, usize),
    pad: (usize, usize),
) -> (NdArray, NdArray, Option<NdArray>) {
    let (n, c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (oc, kh, kw) = (w.dims()[1], w.dims()[2], w.dims()[3]);
    let (oh, ow) = deconv_out_hw(h, wd, (kh, kw), stride, pad);
    assert_eq!(gy.dims(), &[n, oc, oh, ow], "deconv grad shape");
    let geom = Conv2dGeom { kernel: (kh, kw), stride, pad, dilation: (1, 1) };
    let rows = n * h * wd;
    let ockk = oc * kh * kw;
    with_scratch(|s| {
        // im2col(gy) has geometry output (h, wd) by adjointness
        let gycols = ColView { x: gy.data(), c: oc, h: oh, w: ow, oh: h, ow: wd, g: geom };
        let mut gxrows = s.take_uninit(rows * c);
        gemm_any(
            &mut gxrows,
            &Mat::Im2col(gycols),
            &Mat::DenseT { d: w.data(), ld: ockk },
            rows,
            ockk,
            c,
            s,
        );
        let mut gx = s.take_uninit(x.size());
        nhwc_to_nchw(&mut gx, &gxrows, n, c, h, wd);
        s.put(gxrows);
        let mut gw = s.take_uninit(c * ockk);
        gemm_any(&mut gw, &Mat::NhwcT(NhwcView::of(x)), &Mat::Im2col(gycols), c, rows, ockk, s);
        let gb = has_bias.then(|| channel_sums(gy));
        (
            NdArray::from_vec(x.dims(), gx),
            NdArray::from_vec(w.dims(), gw),
            gb,
        )
    })
}

// ---------------------------------------------------------------- helpers

/// `rows[r, c] += bias[c]` over a `[rows, c]` buffer, SIMD-dispatched.
/// All tiers are bit-identical (lane-parallel IEEE adds are the same
/// adds), so this carries no numeric caveat.
fn add_bias_rows(buf: &mut [f32], bias: &[f32], cols: usize) {
    let isa = dispatch::isa();
    for row in buf.chunks_exact_mut(cols) {
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` is only produced by `dispatch` after
            // runtime detection proves avx2+fma executable.
            Isa::Avx2 => unsafe { x86::add_bias_row(row, bias) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: `Isa::Neon` only exists on aarch64, where NEON
            // is an architectural baseline.
            Isa::Neon => unsafe { neon::add_bias_row(row, bias) },
            _ => {
                for (v, &b) in row.iter_mut().zip(bias) {
                    *v += b;
                }
            }
        }
    }
}

/// Elementwise `v = max(v, 0)` over a slice, SIMD-dispatched — the
/// fused-ReLU store of the compiled plan's Affine/Conv fast paths.
/// Bit-identical at every tier to mapping `f32::max(·, 0.0)` (the
/// vector max instructions match its NaN handling, and `-0.0` — the
/// one value where they could differ — cannot reach a fused-ReLU
/// input: those are fresh GEMM/bias outputs, whose round-to-nearest
/// accumulation from a `+0.0` start never yields negative zero).
pub fn relu_slice_inplace(y: &mut [f32]) {
    match dispatch::isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx2` is only produced by `dispatch` after
        // runtime detection proves avx2+fma executable.
        Isa::Avx2 => unsafe { x86::relu_slice(y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Isa::Neon` only exists on aarch64, where NEON is an
        // architectural baseline.
        Isa::Neon => unsafe { neon::relu_slice(y) },
        _ => {
            for v in y.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

/// `t[ni, c, …] += bias[c]` over an NCHW buffer with `plane` = h·w.
fn add_bias_planes(buf: &mut [f32], bias: &[f32], n: usize, c: usize, plane: usize) {
    for ni in 0..n {
        for (cc, &b) in bias.iter().enumerate() {
            for v in &mut buf[(ni * c + cc) * plane..(ni * c + cc + 1) * plane] {
                *v += b;
            }
        }
    }
}

/// `[n, h, w, c]`-rows buffer → NCHW (shared with the int8 conv path).
pub(crate) fn nhwc_to_nchw(dst: &mut [f32], src: &[f32], n: usize, c: usize, h: usize, w: usize) {
    let hw = h * w;
    debug_assert_eq!(dst.len(), n * c * hw);
    for ni in 0..n {
        for cc in 0..c {
            let dplane = &mut dst[(ni * c + cc) * hw..(ni * c + cc + 1) * hw];
            let sbase = ni * hw * c + cc;
            for (p, d) in dplane.iter_mut().enumerate() {
                *d = src[sbase + p * c];
            }
        }
    }
}

/// Per-channel sums of an NCHW tensor (bias gradients), accumulated in
/// the same `(n, spatial)`-ascending order the row-matrix reduction
/// used, so values are unchanged.
fn channel_sums(t: &NdArray) -> NdArray {
    let (n, c) = (t.dims()[0], t.dims()[1]);
    let hw: usize = t.dims()[2..].iter().product();
    let d = t.data();
    let mut out = vec![0.0f32; c];
    for (cc, o) in out.iter_mut().enumerate() {
        for ni in 0..n {
            for &v in &d[(ni * c + cc) * hw..(ni * c + cc + 1) * hw] {
                *o += v;
            }
        }
    }
    NdArray::from_vec(&[c], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn tiled(a: &NdArray, b: &NdArray) -> NdArray {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut out = vec![0.0f32; m * n];
        with_scratch(|s| matmul_into(&mut out, a.data(), b.data(), m, k, n, s));
        NdArray::from_vec(&[m, n], out)
    }

    #[test]
    fn tiled_gemm_matches_naive_large() {
        let mut rng = Rng::new(7);
        // forced past SMALL_FLOPS, with edge tiles on every dimension
        let a = rng.randn(&[61, 83], 1.0);
        let b = rng.randn(&[83, 45], 1.0);
        let got = tiled(&a, &b);
        let want = ops::matmul_naive(&a, &b);
        assert!(
            got.allclose(&want, 1e-4, 1e-4),
            "max diff {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn tiled_gemm_spans_k_blocks() {
        let mut rng = Rng::new(8);
        // k > KC exercises the multi-block accumulate path
        let a = rng.randn(&[9, 2 * KC + 3], 1.0);
        let b = rng.randn(&[2 * KC + 3, 17], 1.0);
        let got = tiled(&a, &b);
        let want = ops::matmul_naive(&a, &b);
        assert!(got.allclose(&want, 1e-3, 1e-3), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn small_gemm_is_exact_vs_naive() {
        let a = NdArray::arange(&[5, 4]);
        let b = NdArray::arange(&[4, 3]);
        assert_eq!(tiled(&a, &b), ops::matmul_naive(&a, &b));
    }

    #[test]
    fn fused_conv_matches_materialized_lowering() {
        let mut rng = Rng::new(9);
        let x = rng.randn(&[2, 3, 9, 8], 1.0);
        let w = rng.randn(&[5, 3, 3, 2], 1.0);
        let bias = rng.randn(&[5], 1.0);
        let g = Conv2dGeom { kernel: (3, 2), stride: (2, 1), pad: (1, 1), dilation: (1, 2) };
        let y = conv2d_forward(&x, &w, Some(&bias), &g);
        // reference: materialized im2col + naive matmul + bias + layout
        let cols = ops::im2col(&x, &g);
        let wr = w.reshape(&[5, 18]).t();
        let mut yr = ops::matmul_naive(&cols, &wr);
        yr = ops::add(&yr, &bias);
        let (oh, ow) = g.out_hw(9, 8);
        let want = yr.reshape(&[2, oh, ow, 5]).transpose(&[0, 3, 1, 2]);
        assert_eq!(y.dims(), want.dims());
        assert!(y.allclose(&want, 1e-4, 1e-4), "max diff {}", y.max_abs_diff(&want));
    }

    #[test]
    fn fused_conv_matches_lowering_on_the_tiled_path() {
        let mut rng = Rng::new(10);
        // rows·ckk·oc = 512·36·8 ≫ SMALL_FLOPS: exercises the im2col
        // panel packer inside the tiled core, with edge tiles
        let x = rng.randn(&[2, 4, 16, 16], 1.0);
        let w = rng.randn(&[8, 4, 3, 3], 1.0);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let y = conv2d_forward(&x, &w, None, &g);
        let cols = ops::im2col(&x, &g);
        let wr = w.reshape(&[8, 36]).t();
        let want =
            ops::matmul_naive(&cols, &wr).reshape(&[2, 16, 16, 8]).transpose(&[0, 3, 1, 2]);
        assert_eq!(y.dims(), want.dims());
        assert!(y.allclose(&want, 1e-4, 1e-4), "max diff {}", y.max_abs_diff(&want));
    }

    #[test]
    fn conv_backward_matches_materialized_formulas() {
        let mut rng = Rng::new(11);
        let x = rng.randn(&[2, 3, 8, 8], 1.0);
        let w = rng.randn(&[4, 3, 3, 3], 1.0);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let gy = rng.randn(&[2, 4, 8, 8], 1.0);
        let (gx, gw, gb) = conv2d_backward(&x, &w, &gy, true, &g);
        // naive reference: materialized rows + naive matmuls
        let gyr = gy.transpose(&[0, 2, 3, 1]).reshape(&[2 * 8 * 8, 4]);
        let wr = w.reshape(&[4, 27]);
        let want_gx = ops::col2im(&ops::matmul_naive(&gyr, &wr), x.dims(), &g);
        let want_gw = ops::matmul_naive(&gyr.t(), &ops::im2col(&x, &g)).reshape(w.dims());
        let want_gb = ops::sum_axis(&gyr, 0, false);
        assert!(gx.allclose(&want_gx, 1e-4, 1e-4), "gx diff {}", gx.max_abs_diff(&want_gx));
        assert!(gw.allclose(&want_gw, 1e-3, 1e-3), "gw diff {}", gw.max_abs_diff(&want_gw));
        let gb = gb.unwrap();
        assert!(gb.allclose(&want_gb, 1e-3, 1e-3), "gb diff {}", gb.max_abs_diff(&want_gb));
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut s = Scratch::new();
        let mut v = s.take(100);
        v[0] = 5.0;
        let cap = v.capacity();
        s.put(v);
        let v2 = s.take(80);
        assert_eq!(v2.capacity(), cap); // same buffer back
        assert!(v2.iter().all(|&x| x == 0.0)); // zeroed
        assert_eq!(v2.len(), 80);
    }

    #[test]
    fn take_uninit_skips_the_memset() {
        let mut s = Scratch::new();
        let mut v = s.take(64);
        v.iter_mut().for_each(|x| *x = 3.0);
        s.put(v);
        // contents unspecified (stale values allowed), length exact
        let v2 = s.take_uninit(32);
        assert_eq!(v2.len(), 32);
        s.put(v2);
        // take() on the same pooled buffer re-zeroes
        let v3 = s.take(48);
        assert_eq!(v3.len(), 48);
        assert!(v3.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn with_scratch_is_reentrant() {
        with_scratch(|outer| {
            let v = outer.take(16);
            let inner_len = with_scratch(|inner| inner.take(8).len());
            assert_eq!(inner_len, 8);
            outer.put(v);
        });
    }

    #[test]
    fn panels_are_32_byte_aligned() {
        let mut s = Scratch::new();
        for len in [1usize, 8, 64, 1000] {
            let p = s.take_panel(len);
            assert_eq!(p.slice().len(), len);
            assert_eq!(p.slice().as_ptr() as usize % 32, 0, "panel window must be aligned");
            s.put_panel(p);
        }
        PACK.with(|c| {
            let mut v = c.borrow_mut();
            let w = aligned_pack(&mut v, 40);
            assert_eq!(w.len(), 40);
            assert_eq!(w.as_ptr() as usize % 32, 0, "pack window must be aligned");
        });
    }

    #[test]
    fn microkernel_tiers_agree_on_tails_and_k_blocks() {
        let mut rng = Rng::new(12);
        // every dimension off the 8-grid, k spanning two KC blocks —
        // the shapes where a vector tile could misread its padding
        for (m, k, n) in [(9, 70, 65), (61, KC + 5, 13), (64, 64, 64), (1, 300, 130)] {
            let a = rng.randn(&[m, k], 1.0);
            let b = rng.randn(&[k, n], 1.0);
            let want = dispatch::with_isa(Isa::Scalar, || tiled(&a, &b));
            for isa in dispatch::available_isas() {
                let got = dispatch::with_isa(isa, || tiled(&a, &b));
                assert!(
                    got.allclose(&want, 1e-5, 1e-6),
                    "[{}] {m}x{k}x{n}: max diff {} vs scalar oracle",
                    isa.name(),
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn relu_and_bias_epilogues_match_scalar_at_every_tier() {
        let mut rng = Rng::new(13);
        let src = rng.randn(&[1037], 1.0); // odd length: vector body + tail
        let bias = rng.randn(&[61], 1.0);
        let mut want_relu = src.data().to_vec();
        for v in &mut want_relu {
            *v = v.max(0.0);
        }
        let mut want_bias = src.data()[..61 * 17].to_vec();
        for row in want_bias.chunks_exact_mut(61) {
            for (v, &b) in row.iter_mut().zip(bias.data()) {
                *v += b;
            }
        }
        for isa in dispatch::available_isas() {
            dispatch::with_isa(isa, || {
                let mut got = src.data().to_vec();
                relu_slice_inplace(&mut got);
                assert_eq!(got, want_relu, "[{}] relu epilogue", isa.name());
                let mut got = src.data()[..61 * 17].to_vec();
                add_bias_rows(&mut got, bias.data(), 61);
                assert_eq!(got, want_bias, "[{}] bias epilogue", isa.name());
            });
        }
    }

    #[test]
    fn recycle_feeds_the_arena() {
        // prime: recycle a uniquely-owned array...
        recycle(NdArray::zeros(&[64]));
        // ...and a shared one (must be a no-op, not a panic)
        let a = NdArray::zeros(&[32]);
        let b = a.clone();
        recycle(a);
        drop(b);
        with_scratch(|s| {
            let v = s.take(10);
            assert_eq!(v.len(), 10);
        });
    }
}
