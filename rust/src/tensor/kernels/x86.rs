//! x86_64 AVX2+FMA microkernels — the vector twins of the scalar
//! kernels in `mod.rs` / `int8.rs`.
//!
//! Every function here is an `unsafe fn` gated on `target_feature`;
//! the only sanctioned route to calling one is a [`super::dispatch`]
//! verdict of [`super::dispatch::Isa::Avx2`], which is never produced
//! without `is_x86_feature_detected!("avx2")` + `("fma")` passing (see
//! that module's safety notes). All loads and stores use the
//! unaligned intrinsics, so panel alignment is a performance property
//! — a misaligned buffer is slow, never UB.
//!
//! Numeric contracts, per kernel:
//! - [`microkernel_f32`]: same k-ascending accumulation order as the
//!   scalar tile but FMA keeps products unrounded — results are within
//!   ≤ 1e-5 relative of the scalar oracle, and bit-stable for a fixed
//!   ISA (dispatch never mixes tiers inside a GEMM).
//! - [`qmicrokernel`]: exact i32 accumulation, bit-identical to the
//!   scalar int8 tile.
//! - [`requantize8`], [`relu_slice`], [`add_bias_row`]: bit-identical
//!   to their scalar expressions (see each doc).

use std::arch::x86_64::*;

use super::int8::{QMR, QNR};
use super::{MR, NR};

/// AVX2+FMA register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]`.
/// Eight ymm accumulators (one 8-lane row each); per k step one B-row
/// load plus eight broadcast-FMAs. Same loop order as the scalar
/// [`super::microkernel`], so the only difference is the unrounded
/// FMA products.
///
/// # Safety
/// Caller must ensure avx2+fma are executable (dispatch does) and that
/// `ap` holds at least `kc·MR` and `bp` at least `kc·NR` elements.
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn microkernel_f32(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    // SAFETY: `ap`/`bp` hold kc·MR / kc·NR elements (caller contract,
    // debug-asserted above), so every `a.add(r)` / B-row load below
    // stays in bounds; `acc` is exactly MR·NR = 8 rows of 8 lanes,
    // matching the eight 8-lane loads/stores. Unaligned intrinsics
    // throughout — no alignment precondition.
    unsafe {
        let mut accv = [_mm256_setzero_ps(); MR];
        for (r, v) in accv.iter_mut().enumerate() {
            *v = _mm256_loadu_ps(acc.as_ptr().add(r * NR));
        }
        let mut a = ap.as_ptr();
        let mut b = bp.as_ptr();
        for _ in 0..kc {
            let bv = _mm256_loadu_ps(b);
            for (r, v) in accv.iter_mut().enumerate() {
                let av = _mm256_broadcast_ss(&*a.add(r));
                *v = _mm256_fmadd_ps(av, bv, *v);
            }
            a = a.add(MR);
            b = b.add(NR);
        }
        for (r, v) in accv.iter().enumerate() {
            _mm256_storeu_ps(acc.as_mut_ptr().add(r * NR), *v);
        }
    }
}

/// AVX2 int8 register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]`
/// in **exact** i32, bit-identical to the scalar
/// [`super::int8::qmicrokernel`].
///
/// This is the `maddubs`-class pairwise widening multiply-accumulate,
/// but in its saturation-free form: `_mm256_maddubs_epi16` sums u8·i8
/// pair products into i16 with *saturation*, and this operand range
/// reaches ±(255·127·2) = ±64770 > i16::MAX — using it would silently
/// clamp and break the exact-accumulation contract the quantizer
/// depends on. Instead, k is consumed two steps at a time with both
/// sides widened to i16 lanes first, then `_mm256_madd_epi16` does the
/// pairwise i16×i16 → i32 multiply-add, which is exact here
/// (2 · 32767² < i32::MAX). The pair interleave only reorders the two
/// addends of each pairwise sum — integer addition commutes, so the
/// result equals the scalar k-ascending accumulation bit for bit.
///
/// # Safety
/// Caller must ensure avx2 is executable (dispatch does) and that `ap`
/// holds at least `k·QMR` and `bp` at least `k·QNR` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn qmicrokernel(k: usize, ap: &[u8], bp: &[i8], acc: &mut [i32; QMR * QNR]) {
    debug_assert!(ap.len() >= k * QMR && bp.len() >= k * QNR);
    // SAFETY: `ap`/`bp` hold k·QMR / k·QNR elements (caller contract,
    // debug-asserted above): every 8-byte B-row load at `kk·QNR` and
    // every A read at `kk·QMR + r` is in bounds for kk < k, r < 8.
    // `acc` is exactly QMR·QNR = 64 i32 = 8 ymm rows, matching the
    // eight 256-bit loads/stores. Unaligned intrinsics throughout.
    unsafe {
        let mut accv = [_mm256_setzero_si256(); QMR];
        for (r, v) in accv.iter_mut().enumerate() {
            *v = _mm256_loadu_si256(acc.as_ptr().add(r * QNR) as *const __m256i);
        }
        let mut kk = 0;
        while kk + 1 < k {
            // interleave B rows kk and kk+1 bytewise, widen to i16:
            // lanes [b0c0, b1c0, b0c1, b1c1, …] — madd's pairwise sum
            // then yields b0c·a0 + b1c·a1 per output column c.
            let b0 = _mm_loadl_epi64(bp.as_ptr().add(kk * QNR) as *const __m128i);
            let b1 = _mm_loadl_epi64(bp.as_ptr().add((kk + 1) * QNR) as *const __m128i);
            let bw = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(b0, b1));
            let a0 = ap.as_ptr().add(kk * QMR);
            let a1 = ap.as_ptr().add((kk + 1) * QMR);
            for (r, v) in accv.iter_mut().enumerate() {
                // the matching [a0r, a1r] pair in every i32 lane
                let pair = *a0.add(r) as u32 | ((*a1.add(r) as u32) << 16);
                let aw = _mm256_set1_epi32(pair as i32);
                *v = _mm256_add_epi32(*v, _mm256_madd_epi16(aw, bw));
            }
            kk += 2;
        }
        if kk < k {
            // odd-k tail: zero partner row, pairwise sum degenerates
            // to the single product
            let b0 = _mm_loadl_epi64(bp.as_ptr().add(kk * QNR) as *const __m128i);
            let bw = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(b0, _mm_setzero_si128()));
            let a0 = ap.as_ptr().add(kk * QMR);
            for (r, v) in accv.iter_mut().enumerate() {
                let aw = _mm256_set1_epi32(*a0.add(r) as i32);
                *v = _mm256_add_epi32(*v, _mm256_madd_epi16(aw, bw));
            }
        }
        for (r, v) in accv.iter().enumerate() {
            _mm256_storeu_si256(acc.as_mut_ptr().add(r * QNR) as *mut __m256i, *v);
        }
    }
}

/// Vectorized int8 epilogue for one full-width (`QNR` = 8) tile row:
/// eight [`super::int8::requantize_one`] evaluations, bit-identical.
/// Why the bits match the scalar expression
/// `(acc − zp·colsum) as f32 * scale + bias` (then `max(·, 0)`):
/// - the integer correction is exact (no overflow by the
///   `MAX_EXACT_K` bound, which caps `zp·colsum` too);
/// - `_mm256_cvtepi32_ps` rounds to nearest-even, exactly like
///   `as f32`;
/// - multiply and add stay **separate** (no FMA — contracting them
///   would change the bits);
/// - a `None` bias adds `+0.0` like the scalar's `map_or(0.0, …)`;
/// - `_mm256_max_ps(v, 0)` returns its second operand for NaN, same
///   as `f32::max(v, 0.0)` → `0.0`, and `-0.0` vs `+0.0` cannot
///   differ here: `v = -0.0` needs `corr = 0` (exact product `+0.0`)
///   plus a negative-zero–producing add, and `+0.0 + ±bias` follows
///   the same IEEE zero-sign rules in both forms.
///
/// # Safety
/// Caller must ensure avx2 is executable (dispatch does) and that
/// `dst`, `acc`, `colsums`, `scales` (and `bias` when present) each
/// hold at least 8 elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn requantize8(
    dst: &mut [f32],
    acc: &[i32],
    zp: u8,
    colsums: &[i32],
    scales: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
) {
    debug_assert!(dst.len() >= 8 && acc.len() >= 8 && colsums.len() >= 8 && scales.len() >= 8);
    debug_assert!(bias.is_none_or(|b| b.len() >= 8));
    // SAFETY: every slice holds ≥ 8 elements (caller contract, debug-
    // asserted above), so each 256-bit unaligned load/store touches
    // exactly the first 8 lanes of a live slice.
    unsafe {
        let accv = _mm256_loadu_si256(acc.as_ptr() as *const __m256i);
        let colv = _mm256_loadu_si256(colsums.as_ptr() as *const __m256i);
        let corr = _mm256_sub_epi32(accv, _mm256_mullo_epi32(_mm256_set1_epi32(zp as i32), colv));
        let prod = _mm256_mul_ps(_mm256_cvtepi32_ps(corr), _mm256_loadu_ps(scales.as_ptr()));
        let biasv = match bias {
            Some(b) => _mm256_loadu_ps(b.as_ptr()),
            None => _mm256_setzero_ps(),
        };
        let mut v = _mm256_add_ps(prod, biasv);
        if relu {
            v = _mm256_max_ps(v, _mm256_setzero_ps());
        }
        _mm256_storeu_ps(dst.as_mut_ptr(), v);
    }
}

/// Vectorized `v = max(v, 0)` over a slice — the fused-ReLU store of
/// the compiled plan. Bit-identical to mapping `f32::max(·, 0.0)`:
/// `max_ps` returns the second operand (0.0) for NaN like `f32::max`,
/// and its `-0.0 → +0.0` preference only differs on `-0.0` inputs,
/// which fused-ReLU feeds (fresh GEMM/bias outputs) cannot produce —
/// accumulators start at `+0.0` and round-to-nearest addition never
/// turns a `+0.0` running sum negative-zero.
///
/// # Safety
/// Caller must ensure avx2 is executable (dispatch does).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn relu_slice(y: &mut [f32]) {
    // SAFETY: `i + 8 <= y.len()` bounds every 8-lane load/store inside
    // the live slice; the scalar tail indexes `i..len` directly.
    unsafe {
        let n = y.len();
        let p = y.as_mut_ptr();
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(p.add(i), _mm256_max_ps(_mm256_loadu_ps(p.add(i)), zero));
            i += 8;
        }
        for j in i..n {
            let v = *p.add(j);
            *p.add(j) = v.max(0.0);
        }
    }
}

/// Vectorized `row[c] += bias[c]` over `min(row, bias)` elements —
/// bit-identical to the scalar zip (IEEE addition is what it is,
/// lane-parallel or not).
///
/// # Safety
/// Caller must ensure avx2 is executable (dispatch does).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn add_bias_row(row: &mut [f32], bias: &[f32]) {
    // SAFETY: `i + 8 <= n ≤ len(row), len(bias)` bounds every 8-lane
    // load/store inside both live slices; the tail indexes `i..n`.
    unsafe {
        let n = row.len().min(bias.len());
        let p = row.as_mut_ptr();
        let b = bias.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_add_ps(_mm256_loadu_ps(p.add(i)), _mm256_loadu_ps(b.add(i)));
            _mm256_storeu_ps(p.add(i), v);
            i += 8;
        }
        for j in i..n {
            *p.add(j) += *b.add(j);
        }
    }
}
