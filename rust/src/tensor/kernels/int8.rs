//! Int8 inference kernels — the quantized sibling of the f32 tiled
//! GEMM one directory up.
//!
//! ## Quantization scheme
//!
//! - **Activations** are asymmetric per-tensor u8: `x ≈ s·(q − zp)`
//!   with `q ∈ [0, 255]` and the zero point chosen so that `x = 0`
//!   maps exactly onto `q = zp` (so conv zero padding quantizes to the
//!   zero point, and the padded im2col view below stays exact).
//! - **Weights** are symmetric per-output-channel i8: `w ≈ s_j·q`
//!   with `q ∈ [−127, 127]` and one scale per GEMM *column* (Affine
//!   output feature / conv output channel).
//!
//! The product accumulates exactly in i32 and dequantizes once per
//! output element: with `acc = Σ_k a_q·w_q` and `colsum_j = Σ_k w_q`,
//!
//! ```text
//! y[i,j] = (acc − zp·colsum_j) · s_a·s_j  (+ bias_j) (→ max(·,0))
//! ```
//!
//! — the standard zero-point correction, fused with bias and ReLU in
//! the epilogue ([`requantize_one`]) so a quantized Affine/Convolution
//! layer is one pass over its output.
//!
//! ## Shape of the kernel
//!
//! The weight matrix is **prepacked once** ([`QMatB`]): `QNR`-wide
//! column panels in k-major order, built at quantize/load time — a
//! serving plan never packs its B side again (the f32 core re-packs
//! per call). Per call only the u8 A panel is packed, one `QMR`-row
//! tile at a time, straight out of a dense row-major buffer or a
//! virtual im2col view of a quantized NCHW image ([`QMatA`]). Row
//! tiles are sharded over [`crate::tensor::parallel`] with the same
//! shape-derived chunking as the f32 core; integer accumulation is
//! exact and the epilogue is a fixed per-element expression, so
//! results are bit-identical at any `NNL_THREADS` by construction.
//!
//! ## SIMD tiers
//!
//! Like the f32 core, the int8 tile and its requantize epilogue have
//! hand-written AVX2/NEON variants behind [`super::dispatch`] — but
//! with a stronger contract: every tier is **bit-identical** to the
//! scalar oracle, not just close. The vector tiles widen through i16
//! and multiply-accumulate into exact i32 (`_mm256_madd_epi16` /
//! `vmlal_s16`); the raw `_mm256_maddubs_epi16` shape is deliberately
//! avoided because its i16 pairwise sums *saturate* for this operand
//! range (see `x86.rs`). The epilogue keeps its multiply and add
//! separate so it computes the exact expression [`requantize_one`]
//! spells. Parity suites therefore assert `==`, never tolerance.

use std::cell::RefCell;

use crate::tensor::ops::Conv2dGeom;
use crate::tensor::{parallel, NdArray};

#[cfg(target_arch = "aarch64")]
use super::neon;
#[cfg(target_arch = "x86_64")]
use super::x86;
use super::{dispatch, dispatch::Isa, nhwc_to_nchw, with_scratch};

/// Microkernel rows (output tile height).
pub(crate) const QMR: usize = 8;
/// Microkernel cols (output tile width).
pub(crate) const QNR: usize = 8;
/// Cap on row chunks per GEMM (same determinism rationale as the f32
/// core: the partition is a pure function of the problem shape).
const QMAX_CHUNKS: usize = 64;

/// Largest reduction depth the i32 accumulator holds exactly:
/// `k · 255 · 127 ≤ i32::MAX`. The quantizer refuses the int8 path for
/// deeper GEMMs (they fall back to f32), so "exact integer
/// accumulation" stays an invariant instead of a hope.
pub const MAX_EXACT_K: usize = (i32::MAX as usize) / (255 * 127);

thread_local! {
    /// Per-thread u8 A-panel pack buffer (the int8 twin of the f32
    /// core's `PACK`).
    static QPACK: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
    /// Per-thread quantized-activation buffer: the layer fronts borrow
    /// it for the u8 copy of their input, so steady-state quantized
    /// serving allocates nothing per request (the int8 analogue of the
    /// scratch arena's role on the f32 path).
    static QACT: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's pooled activation buffer. Reentrancy-
/// safe: the buffer is taken by value, so a nested call sees a fresh
/// (empty) one and no `RefCell` borrow is held across user code.
fn with_act_buffer<R>(f: impl FnOnce(&mut Vec<u8>) -> R) -> R {
    let mut buf = QACT.with(|c| std::mem::take(&mut *c.borrow_mut()));
    let r = f(&mut buf);
    QACT.with(|c| *c.borrow_mut() = buf);
    r
}

// ------------------------------------------------------- activation quant

/// Asymmetric u8 quantization parameters for one activation tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActQuant {
    pub scale: f32,
    pub zero_point: u8,
}

impl ActQuant {
    /// Parameters covering `[lo, hi]` (widened to include 0 so the
    /// zero point is exact). A degenerate range quantizes everything
    /// onto the zero point.
    pub fn from_range(lo: f32, hi: f32) -> ActQuant {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let scale = (hi - lo) / 255.0;
        if scale <= 0.0 || !scale.is_finite() {
            return ActQuant { scale: 1.0, zero_point: 0 };
        }
        let zp = (-lo / scale).round().clamp(0.0, 255.0) as u8;
        ActQuant { scale, zero_point: zp }
    }

    /// Quantize one value (round-to-nearest, saturating).
    #[inline(always)]
    pub fn quantize(&self, v: f32) -> u8 {
        ((v / self.scale).round() + self.zero_point as f32).clamp(0.0, 255.0) as u8
    }

    /// Dequantize one level.
    #[inline(always)]
    pub fn dequantize(&self, q: u8) -> f32 {
        (q as i32 - self.zero_point as i32) as f32 * self.scale
    }
}

/// Quantize a full slice (the per-call activation side).
pub fn quantize_slice(aq: &ActQuant, src: &[f32], dst: &mut Vec<u8>) {
    dst.clear();
    dst.reserve(src.len());
    dst.extend(src.iter().map(|&v| aq.quantize(v)));
}

// ---------------------------------------------------------- packed weights

/// A per-output-channel symmetric i8 weight matrix, prepacked into
/// `QNR`-wide column panels for [`qgemm`]. Logical shape `[k, n]`
/// (GEMM B operand: `k` = input features, `n` = output channels).
#[derive(Debug, Clone, PartialEq)]
pub struct QMatB {
    k: usize,
    n: usize,
    /// Panel layout: `panels[jt·k·QNR + kk·QNR + c] = B[kk, jt·QNR+c]`
    /// (zero past `n`).
    panels: Vec<i8>,
    /// Per-column weight scale, length `n`.
    scales: Vec<f32>,
    /// Per-column sum of quantized weights (zero-point correction).
    colsums: Vec<i32>,
}

impl QMatB {
    fn pack(k: usize, n: usize, q_at: impl Fn(usize, usize) -> i8, scales: Vec<f32>) -> QMatB {
        assert_eq!(scales.len(), n, "one weight scale per output channel");
        // n == 0 packs nothing: qgemm early-returns before touching
        // panels, so no placeholder tile is ever needed
        let n_jtiles = n.div_ceil(QNR);
        let mut panels = vec![0i8; n_jtiles * k * QNR];
        let mut colsums = vec![0i32; n];
        for jt in 0..n_jtiles {
            let panel = &mut panels[jt * k * QNR..(jt + 1) * k * QNR];
            for kk in 0..k {
                for c in 0..QNR {
                    let j = jt * QNR + c;
                    if j < n {
                        let v = q_at(kk, j);
                        panel[kk * QNR + c] = v;
                        colsums[j] += v as i32;
                    }
                }
            }
        }
        QMatB { k, n, panels, scales, colsums }
    }

    /// Build from quantized values laid out row-major `[k, n]` with
    /// per-column scales (Affine weights `[in, out]`, channel axis 1).
    pub fn from_i8_kn(q: &[i8], scales: &[f32], k: usize, n: usize) -> QMatB {
        assert_eq!(q.len(), k * n, "quantized weight size");
        QMatB::pack(k, n, |kk, j| q[kk * n + j], scales.to_vec())
    }

    /// Build from quantized values laid out row-major `[n, k]` with
    /// per-row scales (conv weights `[oc, c·kh·kw]`, channel axis 0):
    /// the GEMM consumes the logical transpose.
    pub fn from_i8_nk(q: &[i8], scales: &[f32], n: usize, k: usize) -> QMatB {
        assert_eq!(q.len(), n * k, "quantized weight size");
        QMatB::pack(k, n, |kk, j| q[j * k + kk], scales.to_vec())
    }

    /// Input features (GEMM k).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output channels (GEMM n).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-output-channel weight scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// In-memory footprint of the packed operand (reporting).
    pub fn bytes(&self) -> usize {
        self.panels.len() + 4 * self.scales.len() + 4 * self.colsums.len()
    }
}

// ------------------------------------------------------------- A operands

/// im2col view over a *quantized* NCHW u8 image; out-of-bounds taps
/// read the zero point, which is exactly what f32 zero padding
/// quantizes to.
#[derive(Clone, Copy)]
pub struct QColView<'a> {
    pub x: &'a [u8],
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub oh: usize,
    pub ow: usize,
    pub g: Conv2dGeom,
    pub zp: u8,
}

impl QColView<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> u8 {
        let ohow = self.oh * self.ow;
        let ni = i / ohow;
        let rem = i % ohow;
        let oy = rem / self.ow;
        let ox = rem % self.ow;
        let (kh, kw) = self.g.kernel;
        let khkw = kh * kw;
        let ci = j / khkw;
        let r = j % khkw;
        let ky = r / kw;
        let kx = r % kw;
        let iy = (oy * self.g.stride.0 + ky * self.g.dilation.0) as isize - self.g.pad.0 as isize;
        let ix = (ox * self.g.stride.1 + kx * self.g.dilation.1) as isize - self.g.pad.1 as isize;
        if iy >= 0 && (iy as usize) < self.h && ix >= 0 && (ix as usize) < self.w {
            self.x[((ni * self.c + ci) * self.h + iy as usize) * self.w + ix as usize]
        } else {
            self.zp
        }
    }
}

/// The u8 A-side operand of [`qgemm`].
pub enum QMatA<'a> {
    /// Row-major `[m, k]`; `ld` = k.
    Dense { d: &'a [u8], ld: usize },
    /// im2col of a quantized NCHW image.
    Im2col(QColView<'a>),
}

impl QMatA<'_> {
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> u8 {
        match self {
            QMatA::Dense { d, ld } => d[i * ld + j],
            QMatA::Im2col(v) => v.at(i, j),
        }
    }
}

// ------------------------------------------------------------------- GEMM

/// The one dequantization expression every int8 output element goes
/// through — kernel and test oracle share it, so parity tests can
/// demand *exact* equality.
#[inline(always)]
pub fn requantize_one(acc: i32, zp: u8, colsum: i32, scale: f32, bias: f32, relu: bool) -> f32 {
    let v = (acc - zp as i32 * colsum) as f32 * scale + bias;
    if relu {
        v.max(0.0)
    } else {
        v
    }
}

/// Fused epilogue spec: per-column combined scale (`act·weight`),
/// optional bias, optional ReLU.
pub struct QEpilogue<'a> {
    /// `scales[j] = act_scale · weight_scale[j]`, length `n`.
    pub scales: &'a [f32],
    pub bias: Option<&'a [f32]>,
    pub relu: bool,
}

/// Pack the `QMR`-row u8 A-panel for rows `i0..` over the full k:
/// `ap[kk·QMR + r] = A[i0+r, kk]`; rows past `m` pack as 0 and their
/// outputs are never written.
fn pack_a_q(a: &QMatA, ap: &mut [u8], m: usize, i0: usize, k: usize) {
    let mh = QMR.min(m - i0);
    for kk in 0..k {
        let dst = &mut ap[kk * QMR..kk * QMR + QMR];
        for (r, slot) in dst.iter_mut().enumerate() {
            *slot = if r < mh { a.at(i0 + r, kk) } else { 0 };
        }
    }
}

/// The register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]` in
/// exact i32 (fixed 8×8 unrolled loops; LLVM vectorizes the `c` loop).
/// Operands are widened through i16 — exact, since u8·i8 products fit
/// i16 ranges on both sides — which is the shape LLVM's widening-
/// multiply vectorization patterns (`pmaddwd`-class) recognize.
#[inline(always)]
fn qmicrokernel(k: usize, ap: &[u8], bp: &[i8], acc: &mut [i32; QMR * QNR]) {
    for kk in 0..k {
        let a = &ap[kk * QMR..kk * QMR + QMR];
        let b = &bp[kk * QNR..kk * QNR + QNR];
        for r in 0..QMR {
            let ar = a[r] as i16 as i32;
            let row = &mut acc[r * QNR..r * QNR + QNR];
            for (o, &bv) in row.iter_mut().zip(b) {
                *o += ar * (bv as i16 as i32);
            }
        }
    }
}

/// Run the int8 register tile on the given tier. Every tier
/// accumulates in exact i32, so the choice is invisible in the output
/// bits — the scalar tile stays the oracle the others are tested
/// against with `==`.
#[inline]
fn run_qmicrokernel(isa: Isa, k: usize, ap: &[u8], bp: &[i8], acc: &mut [i32; QMR * QNR]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx2` is only ever produced by `dispatch` after
        // runtime detection proves avx2 (+fma) executable; slice
        // lengths follow the scalar kernel's own contract.
        Isa::Avx2 => unsafe { x86::qmicrokernel(k, ap, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Isa::Neon` only exists on aarch64, where NEON is an
        // architectural baseline; slice lengths per the shared contract.
        Isa::Neon => unsafe { neon::qmicrokernel(k, ap, bp, acc) },
        _ => qmicrokernel(k, ap, bp, acc),
    }
}

/// Dequantize one tile row: `dst[c] =` [`requantize_one`] of
/// `acc[c]` against column `j0+c`'s metadata. Full-width (`QNR`) rows
/// take the vector epilogue when the tier has one — bit-identical to
/// the scalar loop (see the variants' docs) — and partial tail rows
/// always take the scalar loop.
#[inline]
fn requantize_row(
    isa: Isa,
    dst: &mut [f32],
    acc: &[i32],
    zp: u8,
    colsums: &[i32],
    scales: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
) {
    if dst.len() == QNR {
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` is only ever produced by `dispatch`
            // after runtime detection; all slices hold ≥ QNR = 8
            // elements here (full-width row).
            Isa::Avx2 => {
                unsafe { x86::requantize8(dst, acc, zp, colsums, scales, bias, relu) };
                return;
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: `Isa::Neon` only exists on aarch64 (NEON
            // baseline); all slices hold ≥ QNR = 8 elements here.
            Isa::Neon => {
                unsafe { neon::requantize8(dst, acc, zp, colsums, scales, bias, relu) };
                return;
            }
            _ => {}
        }
    }
    for (c, slot) in dst.iter_mut().enumerate() {
        *slot = requantize_one(
            acc[c],
            zp,
            colsums[c],
            scales[c],
            bias.map_or(0.0, |bb| bb[c]),
            relu,
        );
    }
}

/// `out[m, n] = dequant(A_q[m, k] · B_q[k, n])` with the fused
/// bias/ReLU epilogue. `zp` is the A-side zero point. Row-sharded over
/// the worker pool; bit-identical at any thread count **and at any
/// ISA tier** (exact integer accumulation + an epilogue that computes
/// the exact [`requantize_one`] expression). The tier is resolved once
/// here on the submitting thread and carried into every chunk.
pub fn qgemm(out: &mut [f32], a: &QMatA, zp: u8, b: &QMatB, m: usize, epi: &QEpilogue) {
    let (k, n) = (b.k, b.n);
    debug_assert!(k <= MAX_EXACT_K, "qgemm reduction depth {k} can overflow i32");
    assert_eq!(out.len(), m * n, "qgemm output buffer size");
    assert_eq!(epi.scales.len(), n, "qgemm epilogue scale count");
    if let Some(bias) = epi.bias {
        assert_eq!(bias.len(), n, "qgemm bias size");
    }
    if m == 0 || n == 0 {
        return;
    }
    let isa = dispatch::isa();
    let n_itiles = m.div_ceil(QMR);
    let n_jtiles = n.div_ceil(QNR);
    let chunk_tiles = n_itiles.div_ceil(QMAX_CHUNKS).max(1);
    let chunk_elems = chunk_tiles * QMR * n;
    parallel::for_each_chunk_mut(out, chunk_elems, |ci, chunk| {
        QPACK.with(|p| {
            let mut ap = p.borrow_mut();
            if ap.len() != k * QMR {
                ap.resize(k * QMR, 0);
            }
            debug_assert_eq!(chunk.len() % n, 0);
            let rows_here = chunk.len() / n;
            let row_base = ci * chunk_tiles * QMR;
            let mut local0 = 0;
            while local0 < rows_here {
                let i0 = row_base + local0;
                let mh = QMR.min(rows_here - local0);
                pack_a_q(a, &mut ap, m, i0, k);
                for jt in 0..n_jtiles {
                    let j0 = jt * QNR;
                    let nw = QNR.min(n - j0);
                    let bp = &b.panels[jt * k * QNR..(jt + 1) * k * QNR];
                    let mut acc = [0i32; QMR * QNR];
                    run_qmicrokernel(isa, k, &ap, bp, &mut acc);
                    for r in 0..mh {
                        let dst =
                            &mut chunk[(local0 + r) * n + j0..(local0 + r) * n + j0 + nw];
                        requantize_row(
                            isa,
                            dst,
                            &acc[r * QNR..r * QNR + nw],
                            zp,
                            &b.colsums[j0..j0 + nw],
                            &epi.scales[j0..j0 + nw],
                            epi.bias.map(|bb| &bb[j0..j0 + nw]),
                            epi.relu,
                        );
                    }
                }
                local0 += QMR;
            }
        });
    });
}

// ------------------------------------------------------------ layer fronts

/// Quantized affine: quantize `flatten(x)` rows to u8, run the int8
/// GEMM against the prepacked weights, dequantize + bias (+ ReLU) in
/// the epilogue. `combined[j] = act.scale · weight_scale[j]`.
pub fn qaffine_forward(
    x: &NdArray,
    act: &ActQuant,
    w: &QMatB,
    combined: &[f32],
    bias: Option<&NdArray>,
    relu: bool,
) -> NdArray {
    assert!(x.rank() >= 1, "quantized affine input must have a batch axis");
    let batch = x.dims()[0];
    let feat: usize = x.dims()[1..].iter().product();
    assert_eq!(feat, w.k(), "quantized affine input features {feat} vs weight rows {}", w.k());
    with_act_buffer(|xq| {
        quantize_slice(act, x.data(), xq);
        with_scratch(|s| {
            let mut out = s.take_uninit(batch * w.n());
            let epi = QEpilogue { scales: combined, bias: bias.map(|b| b.data()), relu };
            qgemm(&mut out, &QMatA::Dense { d: xq, ld: feat }, act.zero_point, w, batch, &epi);
            NdArray::from_vec(&[batch, w.n()], out)
        })
    })
}

/// Quantized conv: quantize the NCHW image to u8 once, read its
/// im2col matrix virtually (padding taps yield the zero point), run
/// the int8 GEMM, and lay the rows back out as NCHW.
pub fn qconv2d_forward(
    x: &NdArray,
    act: &ActQuant,
    w: &QMatB,
    combined: &[f32],
    bias: Option<&NdArray>,
    relu: bool,
    g: &Conv2dGeom,
) -> NdArray {
    assert_eq!(x.rank(), 4, "quantized conv expects NCHW input");
    let (n, c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    assert_eq!(
        c * g.kernel.0 * g.kernel.1,
        w.k(),
        "quantized conv input channels {c} x kernel {:?} vs weight k {}",
        g.kernel,
        w.k()
    );
    let (oh, ow) = g.out_hw(h, wd);
    let rows = n * oh * ow;
    let oc = w.n();
    with_act_buffer(|xq| {
        quantize_slice(act, x.data(), xq);
        let cols = QColView { x: xq, c, h, w: wd, oh, ow, g: *g, zp: act.zero_point };
        with_scratch(|s| {
            let mut yrows = s.take_uninit(rows * oc);
            let epi = QEpilogue { scales: combined, bias: bias.map(|b| b.data()), relu };
            qgemm(&mut yrows, &QMatA::Im2col(cols), act.zero_point, w, rows, &epi);
            let mut out = s.take_uninit(rows * oc);
            nhwc_to_nchw(&mut out, &yrows, n, oc, oh, ow);
            s.put(yrows);
            NdArray::from_vec(&[n, oc, oh, ow], out)
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::parallel::with_thread_limit;
    use crate::tensor::Rng;

    /// Per-column symmetric i8 quantization of a row-major `[k, n]`
    /// f32 matrix (test-local; the real path lives in `crate::quant`).
    fn quantize_cols(w: &[f32], k: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
        let mut scales = vec![0.0f32; n];
        for j in 0..n {
            let mut m = 0.0f32;
            for kk in 0..k {
                m = m.max(w[kk * n + j].abs());
            }
            scales[j] = if m > 0.0 { m / 127.0 } else { 1.0 };
        }
        let q: Vec<i8> = w
            .iter()
            .enumerate()
            .map(|(i, &v)| (v / scales[i % n]).round().clamp(-127.0, 127.0) as i8)
            .collect();
        (q, scales)
    }

    #[test]
    fn act_quant_zero_maps_to_zero_point_exactly() {
        let aq = ActQuant::from_range(-3.0, 5.0);
        assert_eq!(aq.quantize(0.0), aq.zero_point);
        assert_eq!(aq.dequantize(aq.zero_point), 0.0);
        // positive-only range still includes 0
        let pos = ActQuant::from_range(2.0, 6.0);
        assert_eq!(pos.quantize(0.0), pos.zero_point);
        assert_eq!(pos.zero_point, 0);
        // degenerate range quantizes onto the zero point
        let flat = ActQuant::from_range(0.0, 0.0);
        assert_eq!(flat.quantize(123.0), 0);
        assert_eq!(flat.scale, 1.0);
    }

    #[test]
    fn act_quant_roundtrip_error_is_within_half_a_step() {
        let aq = ActQuant::from_range(-1.0, 1.0);
        for i in 0..100 {
            let v = -1.0 + 0.02 * i as f32;
            let back = aq.dequantize(aq.quantize(v));
            assert!((back - v).abs() <= aq.scale * 0.5 + 1e-6, "{v} -> {back}");
        }
    }

    #[test]
    fn qgemm_matches_scalar_reference_exactly() {
        let mut rng = Rng::new(21);
        // sizes straddle tile boundaries on both axes
        let (m, k, n) = (13, 37, 11);
        let a = rng.rand(&[m, k], -1.0, 1.0);
        let w = rng.randn(&[k, n], 0.5);
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.1 - 0.3).collect();
        let act = ActQuant::from_range(-1.0, 1.0);
        let (q, wscales) = quantize_cols(w.data(), k, n);
        let b = QMatB::from_i8_kn(&q, &wscales, k, n);
        let combined: Vec<f32> = wscales.iter().map(|s| s * act.scale).collect();
        let mut aq = Vec::new();
        quantize_slice(&act, a.data(), &mut aq);
        let mut got = vec![0.0f32; m * n];
        qgemm(
            &mut got,
            &QMatA::Dense { d: &aq, ld: k },
            act.zero_point,
            &b,
            m,
            &QEpilogue { scales: &combined, bias: Some(&bias), relu: true },
        );
        // scalar oracle over the same quantized operands + epilogue
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                let mut colsum = 0i32;
                for kk in 0..k {
                    acc += aq[i * k + kk] as i32 * q[kk * n + j] as i32;
                    colsum += q[kk * n + j] as i32;
                }
                let want =
                    requantize_one(acc, act.zero_point, colsum, combined[j], bias[j], true);
                assert_eq!(got[i * n + j], want, "mismatch at [{i}, {j}]");
            }
        }
    }

    #[test]
    fn qgemm_bit_identical_at_any_thread_count() {
        let mut rng = Rng::new(22);
        let (m, k, n) = (530, 96, 40); // enough row tiles to shard
        let a = rng.rand(&[m, k], -2.0, 2.0);
        let w = rng.randn(&[k, n], 1.0);
        let act = ActQuant::from_range(-2.0, 2.0);
        let (q, wscales) = quantize_cols(w.data(), k, n);
        let b = QMatB::from_i8_kn(&q, &wscales, k, n);
        let combined: Vec<f32> = wscales.iter().map(|s| s * act.scale).collect();
        let mut aq = Vec::new();
        quantize_slice(&act, a.data(), &mut aq);
        let run = || {
            let mut out = vec![0.0f32; m * n];
            qgemm(
                &mut out,
                &QMatA::Dense { d: &aq, ld: k },
                act.zero_point,
                &b,
                m,
                &QEpilogue { scales: &combined, bias: None, relu: false },
            );
            out
        };
        let serial = with_thread_limit(1, run);
        let parallel = run();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn qgemm_simd_tiers_match_scalar_bit_for_bit() {
        let mut rng = Rng::new(23);
        // odd k exercises the AVX2 pair-tail; m/n tails exercise the
        // partial-row scalar epilogue next to the vector one; k = 1
        // and single rows/cols are the degenerate floors
        for (m, k, n) in [(13, 37, 11), (8, 1, 8), (1, 2, 9), (16, 64, 24), (5, 255, 3)] {
            let a = rng.rand(&[m, k], -2.0, 2.0);
            let w = rng.randn(&[k, n], 1.0);
            let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.05 - 0.2).collect();
            let act = ActQuant::from_range(-2.0, 2.0);
            let (q, wscales) = quantize_cols(w.data(), k, n);
            let b = QMatB::from_i8_kn(&q, &wscales, k, n);
            let combined: Vec<f32> = wscales.iter().map(|s| s * act.scale).collect();
            let mut aq = Vec::new();
            quantize_slice(&act, a.data(), &mut aq);
            let run = |bias: Option<&[f32]>, relu: bool| {
                let mut out = vec![0.0f32; m * n];
                qgemm(
                    &mut out,
                    &QMatA::Dense { d: &aq, ld: k },
                    act.zero_point,
                    &b,
                    m,
                    &QEpilogue { scales: &combined, bias, relu },
                );
                out
            };
            for (bias, relu) in [(None, false), (Some(&bias[..]), true)] {
                let want = dispatch::with_isa(Isa::Scalar, || run(bias, relu));
                for isa in dispatch::available_isas() {
                    let got = dispatch::with_isa(isa, || run(bias, relu));
                    assert_eq!(
                        got,
                        want,
                        "[{}] {m}x{k}x{n} relu={relu} must be bit-identical to scalar",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn qcolview_padding_reads_zero_point() {
        // 1x1x2x2 image, 3x3 kernel, pad 1: corner patches mostly pad
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let x = [10u8, 20, 30, 40];
        let v = QColView { x: &x, c: 1, h: 2, w: 2, oh: 2, ow: 2, g, zp: 7 };
        // row 0 = patch at (0, 0); tap (0, 0) is out of bounds
        assert_eq!(v.at(0, 0), 7);
        // center tap of patch (0, 0) is pixel (0, 0)
        assert_eq!(v.at(0, 4), 10);
        // bottom-right tap of patch (1, 1) is out of bounds
        assert_eq!(v.at(3, 8), 7);
    }
}
