//! One-time runtime CPU-feature dispatch for the SIMD microkernels.
//!
//! The GEMM cores ship hand-written `std::arch` microkernel variants
//! (AVX2+FMA on x86_64, NEON on aarch64) next to the scalar parity
//! oracle. Which tier runs is decided **once per process**: the
//! `NNL_ISA` env var (`scalar|avx2|neon|auto`) wins if set and
//! executable, otherwise CPU features are detected with
//! `is_x86_feature_detected!`. Kernels resolve [`isa`] once at entry
//! on the submitting thread and carry the answer into worker-pool
//! chunks as plain data, so a single GEMM never mixes tiers and the
//! bit-identical-across-`NNL_THREADS` contract holds per ISA.
//!
//! ## Safety backbone
//!
//! Every `unsafe` call into a feature-gated microkernel justifies
//! itself by "this [`Isa`] value came from `dispatch`". That argument
//! is airtight because all three producers of a non-scalar tier check
//! executability first: [`detect`] only returns what
//! `is_x86_feature_detected!` (or the aarch64 NEON baseline) proves,
//! the `NNL_ISA` parser falls back to scalar when the request can't
//! run here, and [`with_isa`] asserts [`available`] before installing
//! its thread-local override.
//!
//! ## Numeric contract per tier
//!
//! - int8: bit-identical to scalar at every ISA (exact i32
//!   accumulation in all variants).
//! - f32: bit-identical across thread counts at any fixed ISA;
//!   ≤ 1e-5 relative of the scalar oracle across ISAs (the FMA
//!   variants keep products unrounded).

use std::cell::Cell;
use std::sync::OnceLock;

/// A microkernel tier the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernels — the parity oracle, always available.
    Scalar,
    /// x86_64 AVX2 + FMA (8-lane f32, `madd`-widened int8).
    Avx2,
    /// aarch64 NEON (2×4-lane f32, `mlal`-widened int8).
    Neon,
}

impl Isa {
    /// The `NNL_ISA` spelling of this tier.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

static DISPATCHED: OnceLock<Isa> = OnceLock::new();

thread_local! {
    /// Per-thread override installed by [`with_isa`]. Thread-local on
    /// purpose: kernels resolve their ISA once at entry on the
    /// submitting thread and carry it into pool chunks as plain data,
    /// so a pin scoped to one bench/test thread can never leak into a
    /// kernel running concurrently on another.
    static OVERRIDE: Cell<Option<Isa>> = const { Cell::new(None) };
}

/// Can this machine execute `isa`?
pub fn available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        // NEON is an architectural baseline of aarch64.
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Every tier this machine can execute, scalar first — the iteration
/// order benches and parity suites use.
pub fn available_isas() -> Vec<Isa> {
    [Isa::Scalar, Isa::Avx2, Isa::Neon].into_iter().filter(|&i| available(i)).collect()
}

/// The best tier the CPU supports (ignoring `NNL_ISA`).
fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            Isa::Avx2
        } else {
            Isa::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Scalar
    }
}

/// `NNL_ISA` + detection → the process-wide tier. An unknown spelling
/// auto-detects; a known tier this machine can't run degrades to
/// scalar (never to a different vector tier — a pin must stay
/// predictable). Both misses warn once on stderr.
fn resolve() -> Isa {
    let Ok(raw) = std::env::var("NNL_ISA") else {
        return detect();
    };
    let want = match raw.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => return detect(),
        "scalar" => Isa::Scalar,
        "avx2" => Isa::Avx2,
        "neon" => Isa::Neon,
        other => {
            eprintln!("NNL_ISA={other:?} is not one of scalar|avx2|neon|auto; auto-detecting");
            return detect();
        }
    };
    if available(want) {
        want
    } else {
        eprintln!(
            "NNL_ISA={} requested but this CPU/arch cannot execute it; falling back to scalar",
            want.name()
        );
        Isa::Scalar
    }
}

/// The tier kernels should run right now on this thread: the
/// [`with_isa`] override if one is installed, else the process-wide
/// decision (made once, from `NNL_ISA` + CPU detection).
pub fn isa() -> Isa {
    if let Some(pinned) = OVERRIDE.with(|c| c.get()) {
        return pinned;
    }
    *DISPATCHED.get_or_init(resolve)
}

/// [`isa`], spelled for logs and bench JSON.
pub fn isa_name() -> &'static str {
    isa().name()
}

/// Run `f` with kernels pinned to `pin` on this thread — the handle
/// parity suites and benches use to compare tiers in-process. Panics
/// if this machine can't execute `pin`: a pin that silently changed
/// what it measures would be worse than no pin. Nests; always
/// restores the previous override, even on unwind.
pub fn with_isa<R>(pin: Isa, f: impl FnOnce() -> R) -> R {
    assert!(
        available(pin),
        "with_isa({}): this machine cannot execute that ISA tier",
        pin.name()
    );
    struct Restore(Option<Isa>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|c| c.replace(Some(pin)));
    let _restore = Restore(prev);
    f()
}

/// CPU features relevant to the kernel tiers, as detected at runtime —
/// recorded into `BENCH_kernels.json` so every measurement names the
/// silicon it ran on.
pub fn cpu_features() -> Vec<&'static str> {
    #[cfg(target_arch = "x86_64")]
    {
        let mut f = vec!["sse2"];
        if std::arch::is_x86_feature_detected!("avx") {
            f.push("avx");
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            f.push("avx2");
        }
        if std::arch::is_x86_feature_detected!("fma") {
            f.push("fma");
        }
        if std::arch::is_x86_feature_detected!("avx512f") {
            f.push("avx512f");
        }
        f
    }
    #[cfg(target_arch = "aarch64")]
    {
        vec!["neon"]
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available_and_dispatch_is_executable() {
        assert!(available(Isa::Scalar));
        let tiers = available_isas();
        assert_eq!(tiers[0], Isa::Scalar);
        assert!(tiers.contains(&isa()), "dispatched tier {:?} must be executable", isa());
    }

    #[test]
    fn with_isa_pins_nests_and_restores() {
        let base = isa();
        with_isa(Isa::Scalar, || {
            assert_eq!(isa(), Isa::Scalar);
            with_isa(Isa::Scalar, || assert_eq!(isa(), Isa::Scalar));
            assert_eq!(isa(), Isa::Scalar);
        });
        assert_eq!(isa(), base);
    }

    #[test]
    fn with_isa_restores_on_unwind() {
        let base = isa();
        let r = std::panic::catch_unwind(|| {
            with_isa(Isa::Scalar, || panic!("boom"));
        });
        assert!(r.is_err());
        assert_eq!(isa(), base);
    }

    #[test]
    fn names_match_the_env_spellings() {
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Avx2.name(), "avx2");
        assert_eq!(Isa::Neon.name(), "neon");
    }
}
