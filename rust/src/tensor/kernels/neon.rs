//! aarch64 NEON microkernels — the vector twins of the scalar kernels
//! in `mod.rs` / `int8.rs`, mirroring `x86.rs` kernel for kernel.
//!
//! NEON is an architectural baseline of aarch64, so
//! [`super::dispatch::Isa::Neon`] is always executable when this
//! module compiles at all; the functions still follow the crate-wide
//! discipline of `unsafe fn` + one SAFETY-documented block, because
//! their bodies are raw-pointer loads and stores. All accesses use
//! the unaligned `vld1`/`vst1` family — panel alignment is a
//! performance property, never a safety precondition.
//!
//! Numeric contracts match `x86.rs`: the f32 tile uses fused
//! multiply-add (`vfmaq_f32`, ≤ 1e-5 relative of the scalar oracle,
//! bit-stable per ISA); the int8 tile and all epilogues are
//! bit-identical to their scalar expressions.

use std::arch::aarch64::*;

use super::int8::{QMR, QNR};
use super::{MR, NR};

/// NEON register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]`.
/// Sixteen q-register accumulators (8 rows × two 4-lane halves); per
/// k step two B loads plus a broadcast-FMA pair per row. Same loop
/// order as the scalar [`super::microkernel`]; the only difference is
/// the unrounded FMA products.
///
/// # Safety
/// Caller must ensure `ap` holds at least `kc·MR` and `bp` at least
/// `kc·NR` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn microkernel_f32(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    // SAFETY: `ap`/`bp` hold kc·MR / kc·NR elements (caller contract,
    // debug-asserted above), so every A read at `kk·MR + r` and both
    // 4-lane B loads at `kk·NR (+4)` are in bounds; `acc` is exactly
    // MR·NR = 64 f32 = 8 rows × two 4-lane halves, matching the
    // sixteen loads/stores. `vld1`/`vst1` have no alignment demands.
    unsafe {
        let mut acc0 = [vdupq_n_f32(0.0); MR];
        let mut acc1 = [vdupq_n_f32(0.0); MR];
        for r in 0..MR {
            acc0[r] = vld1q_f32(acc.as_ptr().add(r * NR));
            acc1[r] = vld1q_f32(acc.as_ptr().add(r * NR + 4));
        }
        for kk in 0..kc {
            let b0 = vld1q_f32(bp.as_ptr().add(kk * NR));
            let b1 = vld1q_f32(bp.as_ptr().add(kk * NR + 4));
            let arow = ap.as_ptr().add(kk * MR);
            for r in 0..MR {
                let av = vdupq_n_f32(*arow.add(r));
                acc0[r] = vfmaq_f32(acc0[r], av, b0);
                acc1[r] = vfmaq_f32(acc1[r], av, b1);
            }
        }
        for r in 0..MR {
            vst1q_f32(acc.as_mut_ptr().add(r * NR), acc0[r]);
            vst1q_f32(acc.as_mut_ptr().add(r * NR + 4), acc1[r]);
        }
    }
}

/// NEON int8 register tile: `acc[r, c] += Σ_kk ap[kk, r] · bp[kk, c]`
/// in **exact** i32, bit-identical to the scalar
/// [`super::int8::qmicrokernel`]: both sides widen to i16 (lossless
/// for u8 and i8) and `vmlal_s16` does i16×i16 → i32 multiply-
/// accumulate, exact for this operand range. Same k-ascending order
/// as the scalar tile — no reassociation at all.
///
/// # Safety
/// Caller must ensure `ap` holds at least `k·QMR` and `bp` at least
/// `k·QNR` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn qmicrokernel(k: usize, ap: &[u8], bp: &[i8], acc: &mut [i32; QMR * QNR]) {
    debug_assert!(ap.len() >= k * QMR && bp.len() >= k * QNR);
    // SAFETY: `ap`/`bp` hold k·QMR / k·QNR elements (caller contract,
    // debug-asserted above): each 8-byte B-row load at `kk·QNR` and
    // each A read at `kk·QMR + r` is in bounds. `acc` is exactly
    // QMR·QNR = 64 i32 = 8 rows × two 4-lane halves, matching the
    // sixteen loads/stores. `vld1`/`vst1` have no alignment demands.
    unsafe {
        let mut acc0 = [vdupq_n_s32(0); QMR];
        let mut acc1 = [vdupq_n_s32(0); QMR];
        for r in 0..QMR {
            acc0[r] = vld1q_s32(acc.as_ptr().add(r * QNR));
            acc1[r] = vld1q_s32(acc.as_ptr().add(r * QNR + 4));
        }
        for kk in 0..k {
            let bw = vmovl_s8(vld1_s8(bp.as_ptr().add(kk * QNR)));
            let blo = vget_low_s16(bw);
            let bhi = vget_high_s16(bw);
            let arow = ap.as_ptr().add(kk * QMR);
            for r in 0..QMR {
                let av = vdup_n_s16(*arow.add(r) as i16);
                acc0[r] = vmlal_s16(acc0[r], av, blo);
                acc1[r] = vmlal_s16(acc1[r], av, bhi);
            }
        }
        for r in 0..QMR {
            vst1q_s32(acc.as_mut_ptr().add(r * QNR), acc0[r]);
            vst1q_s32(acc.as_mut_ptr().add(r * QNR + 4), acc1[r]);
        }
    }
}

/// Vectorized int8 epilogue for one full-width (`QNR` = 8) tile row —
/// eight [`super::int8::requantize_one`] evaluations, bit-identical
/// for the same reasons as the AVX2 variant (exact integer
/// correction, `vcvtq_f32_s32` rounds like `as f32`, separate
/// mul/add, `+0.0` for a `None` bias) with one NEON-specific choice:
/// the ReLU uses `vmaxnmq_f32` (IEEE maxNum), whose NaN-suppressing
/// semantics match `f32::max` — plain `vmaxq_f32` would propagate
/// NaN instead.
///
/// # Safety
/// Caller must ensure `dst`, `acc`, `colsums`, `scales` (and `bias`
/// when present) each hold at least 8 elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn requantize8(
    dst: &mut [f32],
    acc: &[i32],
    zp: u8,
    colsums: &[i32],
    scales: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
) {
    debug_assert!(dst.len() >= 8 && acc.len() >= 8 && colsums.len() >= 8 && scales.len() >= 8);
    debug_assert!(bias.is_none_or(|b| b.len() >= 8));
    // SAFETY: every slice holds ≥ 8 elements (caller contract, debug-
    // asserted above), so the two 4-lane halves at offsets 0 and 4
    // stay inside each live slice.
    unsafe {
        let zpv = vdupq_n_s32(zp as i32);
        for half in 0..2 {
            let o = half * 4;
            let accv = vld1q_s32(acc.as_ptr().add(o));
            let colv = vld1q_s32(colsums.as_ptr().add(o));
            let corr = vsubq_s32(accv, vmulq_s32(zpv, colv));
            let prod = vmulq_f32(vcvtq_f32_s32(corr), vld1q_f32(scales.as_ptr().add(o)));
            let biasv = match bias {
                Some(b) => vld1q_f32(b.as_ptr().add(o)),
                None => vdupq_n_f32(0.0),
            };
            let mut v = vaddq_f32(prod, biasv);
            if relu {
                v = vmaxnmq_f32(v, vdupq_n_f32(0.0));
            }
            vst1q_f32(dst.as_mut_ptr().add(o), v);
        }
    }
}

/// Vectorized `v = max(v, 0)` over a slice — bit-identical to mapping
/// `f32::max(·, 0.0)`: `vmaxnmq_f32` (IEEE maxNum) suppresses NaN to
/// the other operand like `f32::max`, and the `-0.0` vs `+0.0`
/// distinction is unreachable on fused-ReLU inputs (see the AVX2
/// variant's note).
///
/// # Safety
/// No preconditions beyond NEON being executable (aarch64 baseline).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn relu_slice(y: &mut [f32]) {
    // SAFETY: `i + 4 <= y.len()` bounds every 4-lane load/store inside
    // the live slice; the scalar tail indexes `i..len` directly.
    unsafe {
        let n = y.len();
        let p = y.as_mut_ptr();
        let zero = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(p.add(i), vmaxnmq_f32(vld1q_f32(p.add(i)), zero));
            i += 4;
        }
        for j in i..n {
            let v = *p.add(j);
            *p.add(j) = v.max(0.0);
        }
    }
}

/// Vectorized `row[c] += bias[c]` over `min(row, bias)` elements —
/// bit-identical to the scalar zip.
///
/// # Safety
/// No preconditions beyond NEON being executable (aarch64 baseline).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn add_bias_row(row: &mut [f32], bias: &[f32]) {
    // SAFETY: `i + 4 <= n ≤ len(row), len(bias)` bounds every 4-lane
    // load/store inside both live slices; the tail indexes `i..n`.
    unsafe {
        let n = row.len().min(bias.len());
        let p = row.as_mut_ptr();
        let b = bias.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(p.add(i), vaddq_f32(vld1q_f32(p.add(i)), vld1q_f32(b.add(i))));
            i += 4;
        }
        for j in i..n {
            *p.add(j) += *b.add(j);
        }
    }
}
