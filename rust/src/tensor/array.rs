//! `NdArray`: contiguous row-major f32 buffer + shape + storage dtype.

use std::sync::Arc;

use super::{DType, Shape};

/// The core dense tensor. Data is always `Vec<f32>`; the `dtype` tag
/// controls *storage* precision: writes through the quantizing
/// constructors/setters round values to the dtype's grid, simulating
/// half-precision storage (paper §3.3) with f32 compute.
///
/// Storage is **copy-on-write**: `clone()` (and therefore
/// `Variable::data()` and the tape's per-node input gathering) is an
/// O(1) `Arc` bump; the buffer is only copied when a mutation hits a
/// shared array. Value semantics are unchanged — `Arc<Vec<f32>>` keeps
/// arrays `Send + Sync` for the data-parallel communicator.
#[derive(Debug, Clone, PartialEq)]
pub struct NdArray {
    shape: Shape,
    dtype: DType,
    data: Arc<Vec<f32>>,
}

impl NdArray {
    // ---------------------------------------------------------------- ctors

    /// Zeros of the given shape (f32).
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.size();
        NdArray { shape, dtype: DType::F32, data: Arc::new(vec![0.0; n]) }
    }

    /// All elements set to `v` (f32).
    pub fn full(dims: &[usize], v: f32) -> Self {
        let shape = Shape::new(dims);
        let n = shape.size();
        NdArray { shape, dtype: DType::F32, data: Arc::new(vec![v; n]) }
    }

    /// Ones of the given shape (f32).
    pub fn ones(dims: &[usize]) -> Self {
        Self::full(dims, 1.0)
    }

    /// Scalar (rank-0) array.
    pub fn scalar(v: f32) -> Self {
        NdArray { shape: Shape::scalar(), dtype: DType::F32, data: Arc::new(vec![v]) }
    }

    /// From a flat vec; panics if `data.len() != product(dims)`.
    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape::new(dims);
        assert_eq!(shape.size(), data.len(), "shape {shape} does not match data len {}", data.len());
        NdArray { shape, dtype: DType::F32, data: Arc::new(data) }
    }

    /// From a flat slice.
    pub fn from_slice(dims: &[usize], data: &[f32]) -> Self {
        Self::from_vec(dims, data.to_vec())
    }

    /// `0, 1, ..., n-1` reshaped to `dims` (test helper).
    pub fn arange(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Self::from_vec(dims, (0..n).map(|i| i as f32).collect())
    }

    // ------------------------------------------------------------ accessors

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn size(&self) -> usize {
        self.shape.size()
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw access (copy-on-write: a shared buffer is copied
    /// here first). NOTE: bypasses dtype quantization; callers that
    /// write through this on a half-storage array should finish with
    /// [`NdArray::requantize`]. Hoist the returned slice out of inner
    /// loops — each call re-checks buffer uniqueness.
    pub fn data_mut(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data)
    }

    pub fn into_vec(self) -> Vec<f32> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| (*shared).clone())
    }

    /// The backing buffer if this array uniquely owns it, else `None`
    /// (never copies). The scratch arena uses this to recycle dead
    /// intermediates without disturbing shared COW handles.
    pub fn into_unique_vec(self) -> Option<Vec<f32>> {
        Arc::try_unwrap(self.data).ok()
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.shape.flat_index(idx)]
    }

    /// Element write by multi-index (quantized to the storage dtype).
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.shape.flat_index(idx);
        Arc::make_mut(&mut self.data)[i] = self.dtype.quantize(v);
    }

    /// Scalar value of a size-1 array.
    pub fn item(&self) -> f32 {
        assert_eq!(self.size(), 1, "item() on array of size {}", self.size());
        self.data[0]
    }

    // -------------------------------------------------------------- dtype

    /// Cast to a storage dtype (quantizes every element).
    pub fn cast(&self, dtype: DType) -> NdArray {
        let data: Vec<f32> = self.data.iter().map(|&v| dtype.quantize(v)).collect();
        NdArray { shape: self.shape.clone(), dtype, data: Arc::new(data) }
    }

    /// Re-apply this array's dtype quantization in place (after raw
    /// writes through `data_mut`).
    pub fn requantize(&mut self) {
        if self.dtype != DType::F32 {
            let dtype = self.dtype;
            for v in Arc::make_mut(&mut self.data) {
                *v = dtype.quantize(*v);
            }
        }
    }

    /// Set dtype tag *and* quantize in place.
    pub fn set_dtype(&mut self, dtype: DType) {
        self.dtype = dtype;
        self.requantize();
    }

    // -------------------------------------------------------------- shape ops

    /// Reshape (same number of elements). A `usize::MAX` dim means "infer".
    pub fn reshape(&self, dims: &[usize]) -> NdArray {
        let mut dims = dims.to_vec();
        if let Some(pos) = dims.iter().position(|&d| d == usize::MAX) {
            let known: usize = dims.iter().filter(|&&d| d != usize::MAX).product();
            assert!(known > 0 && self.size() % known == 0, "cannot infer reshape dim");
            dims[pos] = self.size() / known;
        }
        let shape = Shape::new(&dims);
        assert_eq!(shape.size(), self.size(), "reshape {} -> {} size mismatch", self.shape, shape);
        NdArray { shape, dtype: self.dtype, data: self.data.clone() }
    }

    /// Permute axes, materializing a new contiguous array.
    pub fn transpose(&self, axes: &[usize]) -> NdArray {
        assert_eq!(axes.len(), self.rank());
        let out_dims: Vec<usize> = axes.iter().map(|&a| self.dims()[a]).collect();
        let out_shape = Shape::new(&out_dims);
        let in_strides = self.shape.strides();
        let mut data = vec![0.0f32; self.size()];
        let mut idx = vec![0usize; self.rank()];
        for (flat, slot) in data.iter_mut().enumerate() {
            // multi-index in the output
            let mut f = flat;
            for i in (0..out_dims.len()).rev() {
                idx[i] = f % out_dims[i];
                f /= out_dims[i];
            }
            let mut src = 0usize;
            for (i, &a) in axes.iter().enumerate() {
                src += idx[i] * in_strides[a];
            }
            *slot = self.data[src];
        }
        NdArray { shape: out_shape, dtype: self.dtype, data: Arc::new(data) }
    }

    /// 2-D transpose shorthand.
    pub fn t(&self) -> NdArray {
        assert_eq!(self.rank(), 2, "t() requires rank 2");
        self.transpose(&[1, 0])
    }

    /// Broadcast to a target shape (materialized).
    pub fn broadcast_to(&self, dims: &[usize]) -> NdArray {
        let target = Shape::new(dims);
        assert!(
            self.shape.broadcast(&target).as_ref() == Some(&target),
            "cannot broadcast {} to {}",
            self.shape,
            target
        );
        let mut data = vec![0.0f32; target.size()];
        for (i, slot) in data.iter_mut().enumerate() {
            *slot = self.data[self.shape.broadcast_source_index(&target, i)];
        }
        NdArray { shape: target, dtype: self.dtype, data: Arc::new(data) }
    }

    /// Concatenate along `axis`.
    pub fn concat(parts: &[&NdArray], axis: usize) -> NdArray {
        assert!(!parts.is_empty());
        let rank = parts[0].rank();
        assert!(axis < rank);
        let mut out_dims = parts[0].dims().to_vec();
        out_dims[axis] = parts.iter().map(|p| p.dims()[axis]).sum();
        for p in parts {
            assert_eq!(p.rank(), rank);
            for d in 0..rank {
                if d != axis {
                    assert_eq!(p.dims()[d], parts[0].dims()[d], "concat dim mismatch");
                }
            }
        }
        let outer: usize = out_dims[..axis].iter().product();
        let inner: usize = out_dims[axis + 1..].iter().product();
        let mut data = Vec::with_capacity(out_dims.iter().product());
        for o in 0..outer {
            for p in parts {
                let pa = p.dims()[axis];
                let start = o * pa * inner;
                data.extend_from_slice(&p.data[start..start + pa * inner]);
            }
        }
        NdArray { shape: Shape::new(&out_dims), dtype: parts[0].dtype, data: Arc::new(data) }
    }

    /// Slice `[start, stop)` along `axis`.
    pub fn slice_axis(&self, axis: usize, start: usize, stop: usize) -> NdArray {
        assert!(axis < self.rank() && start <= stop && stop <= self.dims()[axis]);
        let mut out_dims = self.dims().to_vec();
        out_dims[axis] = stop - start;
        let outer: usize = self.dims()[..axis].iter().product();
        let inner: usize = self.dims()[axis + 1..].iter().product();
        let a = self.dims()[axis];
        let mut data = Vec::with_capacity(outer * (stop - start) * inner);
        for o in 0..outer {
            let base = o * a * inner;
            data.extend_from_slice(&self.data[base + start * inner..base + stop * inner]);
        }
        NdArray { shape: Shape::new(&out_dims), dtype: self.dtype, data: Arc::new(data) }
    }

    // -------------------------------------------------------------- stats

    /// True if any element is NaN or ±Inf (the paper's
    /// `check_inf_or_nan_grad`, Listing 6).
    pub fn has_inf_or_nan(&self) -> bool {
        self.data.iter().any(|v| !v.is_finite())
    }

    /// Sum of all elements.
    pub fn sum_all(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Mean of all elements.
    pub fn mean_all(&self) -> f32 {
        self.sum_all() / self.size() as f32
    }

    /// L2 norm of all elements.
    pub fn norm2(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Index of max element (flat), NaN-safe (see
    /// [`crate::tensor::ops::argmax`]).
    pub fn argmax_flat(&self) -> usize {
        super::ops::argmax(&self.data)
    }

    /// Max |a - b| against another array of the same shape.
    pub fn max_abs_diff(&self, other: &NdArray) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Allclose with absolute + relative tolerance.
    pub fn allclose(&self, other: &NdArray, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctor_shapes() {
        let z = NdArray::zeros(&[2, 3]);
        assert_eq!(z.size(), 6);
        assert_eq!(z.sum_all(), 0.0);
        let o = NdArray::ones(&[4]);
        assert_eq!(o.sum_all(), 4.0);
        let s = NdArray::scalar(2.5);
        assert_eq!(s.item(), 2.5);
        assert_eq!(s.rank(), 0);
    }

    #[test]
    #[should_panic]
    fn from_vec_size_mismatch_panics() {
        NdArray::from_vec(&[2, 2], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn reshape_with_inference() {
        let a = NdArray::arange(&[2, 6]);
        let b = a.reshape(&[3, usize::MAX]);
        assert_eq!(b.dims(), &[3, 4]);
        assert_eq!(b.data(), a.data());
    }

    #[test]
    fn transpose_2d() {
        let a = NdArray::from_slice(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let t = a.t();
        assert_eq!(t.dims(), &[3, 2]);
        assert_eq!(t.data(), &[1., 4., 2., 5., 3., 6.]);
        // double transpose = identity
        assert_eq!(t.t(), a);
    }

    #[test]
    fn transpose_3d_axes() {
        let a = NdArray::arange(&[2, 3, 4]);
        let t = a.transpose(&[2, 0, 1]);
        assert_eq!(t.dims(), &[4, 2, 3]);
        assert_eq!(t.at(&[1, 0, 2]), a.at(&[0, 2, 1]));
    }

    #[test]
    fn broadcast_to_materializes() {
        let a = NdArray::from_slice(&[3, 1], &[1., 2., 3.]);
        let b = a.broadcast_to(&[3, 4]);
        assert_eq!(b.at(&[2, 3]), 3.0);
        assert_eq!(b.at(&[0, 1]), 1.0);
    }

    #[test]
    fn concat_and_slice_inverse() {
        let a = NdArray::arange(&[2, 3]);
        let b = NdArray::full(&[2, 2], 7.0);
        let c = NdArray::concat(&[&a, &b], 1);
        assert_eq!(c.dims(), &[2, 5]);
        assert_eq!(c.slice_axis(1, 0, 3), a);
        assert_eq!(c.slice_axis(1, 3, 5), b);
    }

    #[test]
    fn concat_axis0() {
        let a = NdArray::arange(&[1, 3]);
        let b = NdArray::arange(&[2, 3]);
        let c = NdArray::concat(&[&a, &b], 0);
        assert_eq!(c.dims(), &[3, 3]);
        assert_eq!(c.slice_axis(0, 1, 3), b);
    }

    #[test]
    fn bf16_storage_quantizes_on_set() {
        let mut a = NdArray::zeros(&[2]).cast(DType::BF16);
        a.set(&[0], 1.0 + 2f32.powi(-9));
        assert_ne!(a.at(&[0]), 1.0 + 2f32.powi(-9));
        // f32 path keeps it
        let mut b = NdArray::zeros(&[2]);
        b.set(&[0], 1.0 + 2f32.powi(-9));
        assert_eq!(b.at(&[0]), 1.0 + 2f32.powi(-9));
    }

    #[test]
    fn inf_nan_detection() {
        let mut a = NdArray::zeros(&[3]);
        assert!(!a.has_inf_or_nan());
        a.data_mut()[1] = f32::NAN;
        assert!(a.has_inf_or_nan());
        let mut b = NdArray::zeros(&[3]);
        b.data_mut()[2] = f32::INFINITY;
        assert!(b.has_inf_or_nan());
    }

    #[test]
    fn allclose_tolerances() {
        let a = NdArray::from_slice(&[2], &[1.0, 100.0]);
        let b = NdArray::from_slice(&[2], &[1.0 + 1e-6, 100.0 + 1e-3]);
        assert!(a.allclose(&b, 1e-5, 1e-4));
        assert!(!a.allclose(&b, 1e-7, 1e-7));
        let c = NdArray::from_slice(&[1], &[1.0]);
        assert!(!a.allclose(&c, 1.0, 1.0)); // shape mismatch
    }

    #[test]
    fn argmax_flat_finds_max() {
        let a = NdArray::from_slice(&[4], &[0.1, 3.0, -1.0, 2.0]);
        assert_eq!(a.argmax_flat(), 1);
    }
}
