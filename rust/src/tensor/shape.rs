//! Shape and stride arithmetic: row-major strides, broadcasting rules
//! (NumPy semantics), and flat-index helpers.

/// A tensor shape (row-major). Rank-0 (scalar) is the empty vec.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn scalar() -> Self {
        Shape(vec![])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Total number of elements.
    pub fn size(&self) -> usize {
        self.0.iter().product()
    }

    /// Row-major strides (in elements).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Convert a multi-index to a flat offset.
    pub fn flat_index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.rank());
        let strides = self.strides();
        idx.iter().zip(&strides).map(|(i, s)| i * s).sum()
    }

    /// Convert a flat offset to a multi-index.
    pub fn multi_index(&self, mut flat: usize) -> Vec<usize> {
        let mut idx = vec![0usize; self.rank()];
        for i in (0..self.rank()).rev() {
            idx[i] = flat % self.0[i];
            flat /= self.0[i];
        }
        idx
    }

    /// NumPy broadcast of two shapes. `None` if incompatible.
    pub fn broadcast(&self, other: &Shape) -> Option<Shape> {
        let r = self.rank().max(other.rank());
        let mut out = vec![0usize; r];
        for i in 0..r {
            let a = if i < r - self.rank() { 1 } else { self.0[i - (r - self.rank())] };
            let b = if i < r - other.rank() { 1 } else { other.0[i - (r - other.rank())] };
            out[i] = if a == b {
                a
            } else if a == 1 {
                b
            } else if b == 1 {
                a
            } else {
                return None;
            };
        }
        Some(Shape(out))
    }

    /// Given a broadcast target shape, map a flat index in the target to
    /// the flat index in `self` (dimensions of size 1 repeat).
    pub fn broadcast_source_index(&self, target: &Shape, target_flat: usize) -> usize {
        let tidx = target.multi_index(target_flat);
        let off = target.rank() - self.rank();
        let strides = self.strides();
        let mut flat = 0usize;
        for i in 0..self.rank() {
            let t = tidx[i + off];
            let s = if self.0[i] == 1 { 0 } else { t };
            flat += s * strides[i];
        }
        flat
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, ")")
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(Shape::new(&[2, 3, 4]).strides(), vec![12, 4, 1]);
        assert_eq!(Shape::new(&[5]).strides(), vec![1]);
        assert_eq!(Shape::scalar().strides(), Vec::<usize>::new());
    }

    #[test]
    fn flat_and_multi_index_inverse() {
        let s = Shape::new(&[3, 4, 5]);
        for flat in 0..s.size() {
            assert_eq!(s.flat_index(&s.multi_index(flat)), flat);
        }
    }

    #[test]
    fn broadcast_rules() {
        let a = Shape::new(&[3, 1]);
        let b = Shape::new(&[1, 4]);
        assert_eq!(a.broadcast(&b), Some(Shape::new(&[3, 4])));
        let c = Shape::new(&[2, 3, 4]);
        let d = Shape::new(&[4]);
        assert_eq!(c.broadcast(&d), Some(Shape::new(&[2, 3, 4])));
        let e = Shape::new(&[3]);
        let f = Shape::new(&[4]);
        assert_eq!(e.broadcast(&f), None);
        assert_eq!(Shape::scalar().broadcast(&c), Some(c.clone()));
    }

    #[test]
    fn broadcast_source_index_repeats_size1_dims() {
        let src = Shape::new(&[3, 1]);
        let tgt = Shape::new(&[3, 4]);
        // target (i, j) -> source (i, 0)
        for i in 0..3 {
            for j in 0..4 {
                let tf = tgt.flat_index(&[i, j]);
                assert_eq!(src.broadcast_source_index(&tgt, tf), i);
            }
        }
    }

    #[test]
    fn size_and_rank() {
        assert_eq!(Shape::new(&[2, 3]).size(), 6);
        assert_eq!(Shape::scalar().size(), 1);
        assert_eq!(Shape::scalar().rank(), 0);
    }
}
