//! Deterministic RNG (xoshiro256**). Every stochastic piece of the
//! framework (init, dropout, data synthesis, structure search) draws
//! from this so runs are exactly reproducible — a Neural Network
//! Console requirement ("all trials are recorded automatically").

use super::NdArray;

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Array of iid N(0, std^2).
    pub fn randn(&mut self, dims: &[usize], std: f32) -> NdArray {
        let n: usize = dims.iter().product();
        NdArray::from_vec(dims, (0..n).map(|_| self.normal() * std).collect())
    }

    /// Array of iid U[lo, hi).
    pub fn rand(&mut self, dims: &[usize], lo: f32, hi: f32) -> NdArray {
        let n: usize = dims.iter().product();
        NdArray::from_vec(dims, (0..n).map(|_| self.uniform_range(lo, hi)).collect())
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(1);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn randn_shape_and_scale() {
        let mut r = Rng::new(3);
        let a = r.randn(&[100, 100], 0.01);
        assert_eq!(a.dims(), &[100, 100]);
        assert!(a.norm2() / (a.size() as f32).sqrt() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
