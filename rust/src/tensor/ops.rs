//! Dense compute kernels on [`NdArray`]: broadcasted elementwise ops,
//! axis reductions, matmul (the dynamic-mode hot path), and
//! im2col/col2im (convolution lowering — the same lowering the L1
//! Pallas kernel path uses, so dynamic and static modes agree).

use super::{NdArray, Shape};

// ------------------------------------------------------------------ zip/map

/// Elementwise binary op with NumPy broadcasting.
pub fn zip_broadcast(a: &NdArray, b: &NdArray, f: impl Fn(f32, f32) -> f32) -> NdArray {
    if a.shape() == b.shape() {
        // fast path: same shape, no index math
        let data: Vec<f32> =
            a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        return NdArray::from_vec(a.dims(), data);
    }
    let target = a
        .shape()
        .broadcast(b.shape())
        .unwrap_or_else(|| panic!("cannot broadcast {} with {}", a.shape(), b.shape()));
    let n = target.size();
    let mut data = vec![0.0f32; n];
    for (i, slot) in data.iter_mut().enumerate() {
        let x = a.data()[a.shape().broadcast_source_index(&target, i)];
        let y = b.data()[b.shape().broadcast_source_index(&target, i)];
        *slot = f(x, y);
    }
    NdArray::from_vec(target.dims(), data)
}

/// Elementwise unary map.
pub fn map(a: &NdArray, f: impl Fn(f32) -> f32) -> NdArray {
    NdArray::from_vec(a.dims(), a.data().iter().map(|&x| f(x)).collect())
}

/// NaN-safe argmax over a slice: index of the first greatest non-NaN
/// element; NaNs sort below everything (a row of all NaNs yields 0).
/// This is the one total ordering every prediction path shares —
/// trainer validation, the serving classifier, `NdArray::argmax_flat` —
/// so NaN logits can never panic an evaluation or a request.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    let mut found = false;
    for (i, &v) in xs.iter().enumerate() {
        if !v.is_nan() && (!found || v > best_v) {
            best = i;
            best_v = v;
            found = true;
        }
    }
    best
}

pub fn add(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x + y)
}
pub fn sub(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x - y)
}
pub fn mul(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x * y)
}
pub fn div(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x / y)
}
pub fn scale(a: &NdArray, s: f32) -> NdArray {
    map(a, |x| x * s)
}

/// Reduce a gradient of `target` shape back to `src` shape by summing
/// the broadcast dimensions (the adjoint of `broadcast_to`).
pub fn reduce_to_shape(grad: &NdArray, src: &Shape) -> NdArray {
    if grad.shape() == src {
        return grad.clone();
    }
    let mut out = vec![0.0f32; src.size()];
    for i in 0..grad.size() {
        out[src.broadcast_source_index(grad.shape(), i)] += grad.data()[i];
    }
    NdArray::from_vec(src.dims(), out)
}

// --------------------------------------------------------------- reductions

/// Sum along `axis`, optionally keeping the reduced dim as size 1.
pub fn sum_axis(a: &NdArray, axis: usize, keepdims: bool) -> NdArray {
    assert!(axis < a.rank());
    let dims = a.dims();
    let outer: usize = dims[..axis].iter().product();
    let ax = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = vec![0.0f32; outer * inner];
    for o in 0..outer {
        for k in 0..ax {
            let base = (o * ax + k) * inner;
            for i in 0..inner {
                out[o * inner + i] += a.data()[base + i];
            }
        }
    }
    let mut out_dims: Vec<usize> = dims.to_vec();
    if keepdims {
        out_dims[axis] = 1;
    } else {
        out_dims.remove(axis);
    }
    NdArray::from_vec(&out_dims, out)
}

/// Mean along `axis`.
pub fn mean_axis(a: &NdArray, axis: usize, keepdims: bool) -> NdArray {
    let n = a.dims()[axis] as f32;
    scale(&sum_axis(a, axis, keepdims), 1.0 / n)
}

/// Max along `axis`; also returns flat argmax offsets (for backward).
pub fn max_axis(a: &NdArray, axis: usize, keepdims: bool) -> (NdArray, Vec<usize>) {
    assert!(axis < a.rank());
    let dims = a.dims();
    let outer: usize = dims[..axis].iter().product();
    let ax = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = vec![f32::NEG_INFINITY; outer * inner];
    let mut arg = vec![0usize; outer * inner];
    for o in 0..outer {
        for k in 0..ax {
            let base = (o * ax + k) * inner;
            for i in 0..inner {
                let v = a.data()[base + i];
                if v > out[o * inner + i] {
                    out[o * inner + i] = v;
                    arg[o * inner + i] = base + i;
                }
            }
        }
    }
    let mut out_dims: Vec<usize> = dims.to_vec();
    if keepdims {
        out_dims[axis] = 1;
    } else {
        out_dims.remove(axis);
    }
    (NdArray::from_vec(&out_dims, out), arg)
}

// ------------------------------------------------------------------ matmul

/// 2-D matrix multiply `[m,k]·[k,n] -> [m,n]`.
///
/// Blocked i-k-j loop with a transposed-B-free inner loop: the k-major
/// ordering keeps both `b` row and `out` row streaming, which is the
/// standard cache-friendly form (this is the dynamic-mode hot path; the
/// static mode runs the Pallas/XLA kernel instead).
pub fn matmul(a: &NdArray, b: &NdArray) -> NdArray {
    assert_eq!(a.rank(), 2, "matmul lhs must be rank 2");
    assert_eq!(b.rank(), 2, "matmul rhs must be rank 2");
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // i-k-j with 64-wide k blocking (KB sweep 64→512 measured neutral;
    // 64 keeps the working set bounded for large k)
    const KB: usize = 64;
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KB).min(k);
            for kk in k0..k1 {
                let av = arow[kk];
                let brow = &bd[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            k0 = k1;
        }
    }
    NdArray::from_vec(&[m, n], out)
}

/// Batched matmul: `[b,m,k]·[b,k,n] -> [b,m,n]`.
pub fn batch_matmul(a: &NdArray, b: &NdArray) -> NdArray {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (bs, m, k) = (a.dims()[0], a.dims()[1], a.dims()[2]);
    let (bs2, k2, n) = (b.dims()[0], b.dims()[1], b.dims()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let mut out = Vec::with_capacity(bs * m * n);
    for i in 0..bs {
        let ai = NdArray::from_slice(&[m, k], &a.data()[i * m * k..(i + 1) * m * k]);
        let bi = NdArray::from_slice(&[k, n], &b.data()[i * k * n..(i + 1) * k * n]);
        out.extend_from_slice(matmul(&ai, &bi).data());
    }
    NdArray::from_vec(&[bs, m, n], out)
}

// ---------------------------------------------------------------- im2col

/// Convolution geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub kernel: (usize, usize),
    pub stride: (usize, usize),
    pub pad: (usize, usize),
    pub dilation: (usize, usize),
}

impl Conv2dGeom {
    pub fn simple(kh: usize, kw: usize) -> Self {
        Conv2dGeom { kernel: (kh, kw), stride: (1, 1), pad: (0, 0), dilation: (1, 1) }
    }

    /// Output spatial size for an input of `(h, w)`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let eff_kh = self.dilation.0 * (self.kernel.0 - 1) + 1;
        let eff_kw = self.dilation.1 * (self.kernel.1 - 1) + 1;
        let oh = (h + 2 * self.pad.0 - eff_kh) / self.stride.0 + 1;
        let ow = (w + 2 * self.pad.1 - eff_kw) / self.stride.1 + 1;
        (oh, ow)
    }
}

/// im2col: `[n,c,h,w] -> [n*oh*ow, c*kh*kw]`. Convolution then reduces
/// to a matmul against reshaped weights `[c*kh*kw, oc]` — the same
/// lowering `python/compile/kernels/matmul.py` feeds.
pub fn im2col(x: &NdArray, g: &Conv2dGeom) -> NdArray {
    assert_eq!(x.rank(), 4, "im2col expects NCHW");
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (kh, kw) = g.kernel;
    let (oh, ow) = g.out_hw(h, w);
    let cols = c * kh * kw;
    let mut out = vec![0.0f32; n * oh * ow * cols];
    let xd = x.data();
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ci in 0..c {
                    for ky in 0..kh {
                        let iy = (oy * g.stride.0 + ky * g.dilation.0) as isize - g.pad.0 as isize;
                        for kx in 0..kw {
                            let ix =
                                (ox * g.stride.1 + kx * g.dilation.1) as isize - g.pad.1 as isize;
                            let col = (ci * kh + ky) * kw + kx;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                out[row + col] = xd
                                    [((ni * c + ci) * h + iy as usize) * w + ix as usize];
                            }
                        }
                    }
                }
            }
        }
    }
    NdArray::from_vec(&[n * oh * ow, cols], out)
}

/// col2im: adjoint of [`im2col`] — scatters column gradients back to
/// the input layout (accumulating where patches overlap).
pub fn col2im(cols: &NdArray, x_dims: &[usize], g: &Conv2dGeom) -> NdArray {
    let (n, c, h, w) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
    let (kh, kw) = g.kernel;
    let (oh, ow) = g.out_hw(h, w);
    let ncols = c * kh * kw;
    assert_eq!(cols.dims(), &[n * oh * ow, ncols]);
    let mut out = vec![0.0f32; n * c * h * w];
    let cd = cols.data();
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * ncols;
                for ci in 0..c {
                    for ky in 0..kh {
                        let iy = (oy * g.stride.0 + ky * g.dilation.0) as isize - g.pad.0 as isize;
                        for kx in 0..kw {
                            let ix =
                                (ox * g.stride.1 + kx * g.dilation.1) as isize - g.pad.1 as isize;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                let col = (ci * kh + ky) * kw + kx;
                                out[((ni * c + ci) * h + iy as usize) * w + ix as usize] +=
                                    cd[row + col];
                            }
                        }
                    }
                }
            }
        }
    }
    NdArray::from_vec(x_dims, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_broadcast_bias() {
        let x = NdArray::arange(&[2, 3]);
        let b = NdArray::from_slice(&[3], &[10., 20., 30.]);
        let y = add(&x, &b);
        assert_eq!(y.data(), &[10., 21., 32., 13., 24., 35.]);
    }

    #[test]
    fn reduce_to_shape_is_broadcast_adjoint() {
        let g = NdArray::ones(&[2, 3]);
        let r = reduce_to_shape(&g, &Shape::new(&[3]));
        assert_eq!(r.data(), &[2., 2., 2.]);
        let r2 = reduce_to_shape(&g, &Shape::new(&[2, 1]));
        assert_eq!(r2.data(), &[3., 3.]);
        let r3 = reduce_to_shape(&g, &Shape::scalar());
        assert_eq!(r3.item(), 6.0);
    }

    #[test]
    fn sum_mean_max_axis() {
        let a = NdArray::from_slice(&[2, 3], &[1., 5., 3., 4., 2., 6.]);
        assert_eq!(sum_axis(&a, 0, false).data(), &[5., 7., 9.]);
        assert_eq!(sum_axis(&a, 1, false).data(), &[9., 12.]);
        assert_eq!(sum_axis(&a, 1, true).dims(), &[2, 1]);
        assert_eq!(mean_axis(&a, 1, false).data(), &[3., 4.]);
        let (m, arg) = max_axis(&a, 1, false);
        assert_eq!(m.data(), &[5., 6.]);
        assert_eq!(arg, vec![1, 5]);
    }

    #[test]
    fn matmul_small() {
        let a = NdArray::from_slice(&[2, 2], &[1., 2., 3., 4.]);
        let b = NdArray::ones(&[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let a = NdArray::arange(&[3, 3]);
        let mut i = NdArray::zeros(&[3, 3]);
        for d in 0..3 {
            i.set(&[d, d], 1.0);
        }
        assert_eq!(matmul(&a, &i), a);
        assert_eq!(matmul(&i, &a), a);
    }

    #[test]
    fn matmul_rect() {
        // [1,3]x[3,2]
        let a = NdArray::from_slice(&[1, 3], &[1., 2., 3.]);
        let b = NdArray::from_slice(&[3, 2], &[1., 4., 2., 5., 3., 6.]);
        let c = matmul(&a, &b);
        assert_eq!(c.dims(), &[1, 2]);
        assert_eq!(c.data(), &[14., 32.]);
    }

    #[test]
    fn batch_matmul_matches_loop() {
        let a = NdArray::arange(&[2, 2, 3]);
        let b = NdArray::arange(&[2, 3, 2]);
        let c = batch_matmul(&a, &b);
        for i in 0..2 {
            let ai = a.slice_axis(0, i, i + 1).reshape(&[2, 3]);
            let bi = b.slice_axis(0, i, i + 1).reshape(&[3, 2]);
            let ci = c.slice_axis(0, i, i + 1).reshape(&[2, 2]);
            assert_eq!(matmul(&ai, &bi), ci);
        }
    }

    #[test]
    fn conv_geom_output_size() {
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        assert_eq!(g.out_hw(8, 8), (8, 8)); // same padding
        let g2 = Conv2dGeom { kernel: (2, 2), stride: (2, 2), pad: (0, 0), dilation: (1, 1) };
        assert_eq!(g2.out_hw(8, 8), (4, 4));
        let g3 = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (0, 0), dilation: (2, 2) };
        assert_eq!(g3.out_hw(8, 8), (4, 4));
    }

    #[test]
    fn im2col_1x1_is_reshape_transpose() {
        let x = NdArray::arange(&[1, 2, 2, 2]);
        let g = Conv2dGeom::simple(1, 1);
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 2]);
        // row (y,x), col c -> x[0, c, y, x]
        assert_eq!(c.at(&[0, 0]), x.at(&[0, 0, 0, 0]));
        assert_eq!(c.at(&[3, 1]), x.at(&[0, 1, 1, 1]));
    }

    #[test]
    fn im2col_known_values() {
        // 1x1x3x3 input, 2x2 kernel, no pad, stride 1 -> 4 patches
        let x = NdArray::arange(&[1, 1, 3, 3]);
        let g = Conv2dGeom::simple(2, 2);
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 4]);
        assert_eq!(&c.data()[0..4], &[0., 1., 3., 4.]); // top-left patch
        assert_eq!(&c.data()[12..16], &[4., 5., 7., 8.]); // bottom-right patch
    }

    #[test]
    fn im2col_padding_zero_fills() {
        let x = NdArray::ones(&[1, 1, 2, 2]);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 9]);
        // top-left patch has 5 zeros (border) + 4 ones
        let row0: f32 = c.data()[0..9].iter().sum();
        assert_eq!(row0, 4.0);
    }

    #[test]
    fn argmax_is_nan_safe() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        // regression: partial_cmp().unwrap() panicked on NaN logits
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN, -1.0]), 2);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0); // first max wins
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn col2im_is_im2col_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> for random-ish x, y
        let x = NdArray::arange(&[2, 2, 4, 4]);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let cx = im2col(&x, &g);
        let y = NdArray::arange(cx.dims());
        let lhs: f32 = cx.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let cty = col2im(&y, x.dims(), &g);
        let rhs: f32 = x.data().iter().zip(cty.data()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-5);
    }
}
