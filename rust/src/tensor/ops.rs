//! Dense compute kernels on [`NdArray`]: broadcasted elementwise ops,
//! axis reductions, matmul (the dynamic-mode hot path), and
//! im2col/col2im (convolution lowering — the same lowering the L1
//! Pallas kernel path uses, so dynamic and static modes agree).
//!
//! Large maps, reductions, matmul and the im2col/col2im lowering are
//! sharded over [`crate::tensor::parallel`]'s worker pool; matmul
//! additionally routes through [`crate::tensor::kernels`]'s packed
//! tiled GEMM. Every parallel split here follows the pool's
//! determinism contract (each output element computed wholly inside
//! one shape-derived chunk), so results are bit-identical at any
//! `NNL_THREADS`. [`matmul_naive`] keeps the pre-tiling single-thread
//! loop as the oracle for property tests and the kernel bench.

use super::{kernels, parallel, NdArray, Shape};

/// Below this many scalar ops, parallel fan-out costs more than it
/// saves; kernels fall back to the identical serial loop.
const PAR_MIN: usize = 16 * 1024;

/// Elementwise chunk length: a pure function of `n` (determinism), at
/// most 64 chunks, each at least 4k elements.
fn par_chunk_len(n: usize) -> usize {
    n.div_ceil(64).max(4096)
}

// ------------------------------------------------------------------ zip/map

/// Elementwise binary op with NumPy broadcasting.
pub fn zip_broadcast(a: &NdArray, b: &NdArray, f: impl Fn(f32, f32) -> f32 + Sync) -> NdArray {
    if a.shape() == b.shape() {
        // fast path: same shape, no index math
        let (ad, bd) = (a.data(), b.data());
        let n = ad.len();
        let mut data = vec![0.0f32; n];
        if n < PAR_MIN {
            for (slot, (&x, &y)) in data.iter_mut().zip(ad.iter().zip(bd)) {
                *slot = f(x, y);
            }
        } else {
            let chunk = par_chunk_len(n);
            parallel::for_each_chunk_mut(&mut data, chunk, |ci, out| {
                let base = ci * chunk;
                for (j, slot) in out.iter_mut().enumerate() {
                    *slot = f(ad[base + j], bd[base + j]);
                }
            });
        }
        return NdArray::from_vec(a.dims(), data);
    }
    let target = a
        .shape()
        .broadcast(b.shape())
        .unwrap_or_else(|| panic!("cannot broadcast {} with {}", a.shape(), b.shape()));
    let n = target.size();
    let mut data = vec![0.0f32; n];
    let at = |i: usize| {
        let x = a.data()[a.shape().broadcast_source_index(&target, i)];
        let y = b.data()[b.shape().broadcast_source_index(&target, i)];
        f(x, y)
    };
    if n < PAR_MIN {
        for (i, slot) in data.iter_mut().enumerate() {
            *slot = at(i);
        }
    } else {
        let chunk = par_chunk_len(n);
        parallel::for_each_chunk_mut(&mut data, chunk, |ci, out| {
            let base = ci * chunk;
            for (j, slot) in out.iter_mut().enumerate() {
                *slot = at(base + j);
            }
        });
    }
    NdArray::from_vec(target.dims(), data)
}

/// Elementwise unary map.
pub fn map(a: &NdArray, f: impl Fn(f32) -> f32 + Sync) -> NdArray {
    let ad = a.data();
    let n = ad.len();
    if n < PAR_MIN {
        return NdArray::from_vec(a.dims(), ad.iter().map(|&x| f(x)).collect());
    }
    let mut data = vec![0.0f32; n];
    let chunk = par_chunk_len(n);
    parallel::for_each_chunk_mut(&mut data, chunk, |ci, out| {
        let base = ci * chunk;
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = f(ad[base + j]);
        }
    });
    NdArray::from_vec(a.dims(), data)
}

/// NaN-safe argmax over a slice: index of the first greatest non-NaN
/// element; NaNs sort below everything (a row of all NaNs yields 0).
/// This is the one total ordering every prediction path shares —
/// trainer validation, the serving classifier, `NdArray::argmax_flat` —
/// so NaN logits can never panic an evaluation or a request.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    let mut found = false;
    for (i, &v) in xs.iter().enumerate() {
        if !v.is_nan() && (!found || v > best_v) {
            best = i;
            best_v = v;
            found = true;
        }
    }
    best
}

pub fn add(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x + y)
}
pub fn sub(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x - y)
}
pub fn mul(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x * y)
}
pub fn div(a: &NdArray, b: &NdArray) -> NdArray {
    zip_broadcast(a, b, |x, y| x / y)
}
pub fn scale(a: &NdArray, s: f32) -> NdArray {
    map(a, |x| x * s)
}

/// Reduce a gradient of `target` shape back to `src` shape by summing
/// the broadcast dimensions (the adjoint of `broadcast_to`).
pub fn reduce_to_shape(grad: &NdArray, src: &Shape) -> NdArray {
    if grad.shape() == src {
        return grad.clone();
    }
    let mut out = vec![0.0f32; src.size()];
    for i in 0..grad.size() {
        out[src.broadcast_source_index(grad.shape(), i)] += grad.data()[i];
    }
    NdArray::from_vec(src.dims(), out)
}

// --------------------------------------------------------------- reductions

/// Sum along `axis`, optionally keeping the reduced dim as size 1.
/// Parallel over output rows: each output element accumulates its
/// whole k-run inside one chunk, so the float order never changes.
pub fn sum_axis(a: &NdArray, axis: usize, keepdims: bool) -> NdArray {
    assert!(axis < a.rank());
    let dims = a.dims();
    let outer: usize = dims[..axis].iter().product();
    let ax = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = vec![0.0f32; outer * inner];
    let ad = a.data();
    if outer * ax * inner < PAR_MIN {
        for o in 0..outer {
            for k in 0..ax {
                let base = (o * ax + k) * inner;
                for i in 0..inner {
                    out[o * inner + i] += ad[base + i];
                }
            }
        }
    } else {
        let chunk_outer = outer.div_ceil(64).max(1);
        parallel::for_each_chunk_mut(&mut out, chunk_outer * inner, |ci, chunk| {
            let o0 = ci * chunk_outer;
            for (r, orow) in chunk.chunks_exact_mut(inner).enumerate() {
                let o = o0 + r;
                for k in 0..ax {
                    let base = (o * ax + k) * inner;
                    for (i, slot) in orow.iter_mut().enumerate() {
                        *slot += ad[base + i];
                    }
                }
            }
        });
    }
    let mut out_dims: Vec<usize> = dims.to_vec();
    if keepdims {
        out_dims[axis] = 1;
    } else {
        out_dims.remove(axis);
    }
    NdArray::from_vec(&out_dims, out)
}

/// Mean along `axis`.
pub fn mean_axis(a: &NdArray, axis: usize, keepdims: bool) -> NdArray {
    let n = a.dims()[axis] as f32;
    scale(&sum_axis(a, axis, keepdims), 1.0 / n)
}

/// Max along `axis`; also returns flat argmax offsets (for backward).
pub fn max_axis(a: &NdArray, axis: usize, keepdims: bool) -> (NdArray, Vec<usize>) {
    assert!(axis < a.rank());
    let dims = a.dims();
    let outer: usize = dims[..axis].iter().product();
    let ax = dims[axis];
    let inner: usize = dims[axis + 1..].iter().product();
    let mut out = vec![f32::NEG_INFINITY; outer * inner];
    let mut arg = vec![0usize; outer * inner];
    for o in 0..outer {
        for k in 0..ax {
            let base = (o * ax + k) * inner;
            for i in 0..inner {
                let v = a.data()[base + i];
                if v > out[o * inner + i] {
                    out[o * inner + i] = v;
                    arg[o * inner + i] = base + i;
                }
            }
        }
    }
    let mut out_dims: Vec<usize> = dims.to_vec();
    if keepdims {
        out_dims[axis] = 1;
    } else {
        out_dims.remove(axis);
    }
    (NdArray::from_vec(&out_dims, out), arg)
}

// ------------------------------------------------------------------ matmul

/// 2-D matrix multiply `[m,k]·[k,n] -> [m,n]` through the packed,
/// register-tiled, row-sharded GEMM in [`crate::tensor::kernels`]
/// (this is the dynamic-mode hot path; the static mode runs the
/// Pallas/XLA kernel instead). Small products take the same serial
/// blocked loop as [`matmul_naive`].
pub fn matmul(a: &NdArray, b: &NdArray) -> NdArray {
    assert_eq!(a.rank(), 2, "matmul lhs must be rank 2");
    assert_eq!(b.rank(), 2, "matmul rhs must be rank 2");
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    kernels::with_scratch(|s| kernels::matmul_into(&mut out, a.data(), b.data(), m, k, n, s));
    NdArray::from_vec(&[m, n], out)
}

/// The pre-tiling matmul: single-thread blocked i-k-j loop. Kept as
/// the oracle for the kernel property tests and as the baseline the
/// `kernel_gemm` bench measures speedups against.
pub fn matmul_naive(a: &NdArray, b: &NdArray) -> NdArray {
    assert_eq!(a.rank(), 2, "matmul lhs must be rank 2");
    assert_eq!(b.rank(), 2, "matmul rhs must be rank 2");
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // i-k-j with 64-wide k blocking (KB sweep 64→512 measured neutral;
    // 64 keeps the working set bounded for large k)
    const KB: usize = 64;
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KB).min(k);
            for kk in k0..k1 {
                let av = arow[kk];
                let brow = &bd[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            k0 = k1;
        }
    }
    NdArray::from_vec(&[m, n], out)
}

/// Batched matmul: `[b,m,k]·[b,k,n] -> [b,m,n]`. Operates on the batch
/// sub-slices directly (no per-slice `NdArray` copies — this sits on
/// the serve micro-batch path) and shards whole batches across the
/// pool; each batch's GEMM writes its own disjoint output block.
pub fn batch_matmul(a: &NdArray, b: &NdArray) -> NdArray {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (bs, m, k) = (a.dims()[0], a.dims()[1], a.dims()[2]);
    let (bs2, k2, n) = (b.dims()[0], b.dims()[1], b.dims()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; bs * m * n];
    let ad = a.data();
    let bd = b.data();
    if m * n > 0 {
        if bs == 1 || bs * m * k * n < PAR_MIN {
            // tiny batches: don't occupy the pool's job slot — the
            // per-batch GEMM (run inline) may still parallelize itself
            for (i, oi) in out.chunks_exact_mut(m * n).enumerate() {
                kernels::with_scratch(|s| {
                    kernels::matmul_into(
                        oi,
                        &ad[i * m * k..(i + 1) * m * k],
                        &bd[i * k * n..(i + 1) * k * n],
                        m,
                        k,
                        n,
                        s,
                    );
                });
            }
        } else {
            parallel::for_each_chunk_mut(&mut out, m * n, |i, oi| {
                kernels::with_scratch(|s| {
                    kernels::matmul_into(
                        oi,
                        &ad[i * m * k..(i + 1) * m * k],
                        &bd[i * k * n..(i + 1) * k * n],
                        m,
                        k,
                        n,
                        s,
                    );
                });
            });
        }
    }
    NdArray::from_vec(&[bs, m, n], out)
}

// ---------------------------------------------------------------- im2col

/// Convolution geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub kernel: (usize, usize),
    pub stride: (usize, usize),
    pub pad: (usize, usize),
    pub dilation: (usize, usize),
}

impl Conv2dGeom {
    pub fn simple(kh: usize, kw: usize) -> Self {
        Conv2dGeom { kernel: (kh, kw), stride: (1, 1), pad: (0, 0), dilation: (1, 1) }
    }

    /// Output spatial size for an input of `(h, w)`, or `None` when
    /// the geometry is degenerate: zero kernel/stride/dilation, or an
    /// effective kernel larger than the padded input (the latter used
    /// to underflow `usize` — same bug class as `pool_out_hw`,
    /// reachable from untrusted NNP attributes). [`crate::nnp::Op`]
    /// validation calls this so malformed files fail at load.
    pub fn try_out_hw(&self, h: usize, w: usize) -> Option<(usize, usize)> {
        let (kh, kw) = self.kernel;
        let (sh, sw) = self.stride;
        let (dh, dw) = self.dilation;
        if kh == 0 || kw == 0 || sh == 0 || sw == 0 || dh == 0 || dw == 0 {
            return None;
        }
        let eff_kh = dh.checked_mul(kh - 1)?.checked_add(1)?;
        let eff_kw = dw.checked_mul(kw - 1)?.checked_add(1)?;
        let oh = (h + 2 * self.pad.0).checked_sub(eff_kh)? / sh + 1;
        let ow = (w + 2 * self.pad.1).checked_sub(eff_kw)? / sw + 1;
        Some((oh, ow))
    }

    /// Output spatial size for an input of `(h, w)`; panics on
    /// degenerate geometry (validated callers use [`Self::try_out_hw`]
    /// first and turn `None` into a load-time error).
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        self.try_out_hw(h, w).unwrap_or_else(|| {
            panic!(
                "convolution geometry invalid on {h}x{w} input: kernel {:?} stride {:?} \
                 pad {:?} dilation {:?}",
                self.kernel, self.stride, self.pad, self.dilation
            )
        })
    }
}

/// im2col: `[n,c,h,w] -> [n*oh*ow, c*kh*kw]`. Convolution then reduces
/// to a matmul against reshaped weights `[c*kh*kw, oc]` — the same
/// lowering `python/compile/kernels/matmul.py` feeds. (The fused conv
/// kernels never materialize this matrix; this entry remains for the
/// oracle tests and any caller that wants the columns themselves.)
/// Rows are sharded across the pool; each row is written by one chunk.
pub fn im2col(x: &NdArray, g: &Conv2dGeom) -> NdArray {
    assert_eq!(x.rank(), 4, "im2col expects NCHW");
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (kh, kw) = g.kernel;
    let (oh, ow) = g.out_hw(h, w);
    let cols = c * kh * kw;
    let rows = n * oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    let xd = x.data();
    if !out.is_empty() {
        // below the parallel threshold a single chunk runs inline
        // (no pool job), with the identical per-row loop
        let chunk_rows =
            if rows * cols < PAR_MIN { rows } else { rows.div_ceil(64).max(1) };
        parallel::for_each_chunk_mut(&mut out, chunk_rows * cols, |chunk_i, chunk| {
            let r0 = chunk_i * chunk_rows;
            for (lr, orow) in chunk.chunks_exact_mut(cols).enumerate() {
                let row = r0 + lr;
                let ni = row / (oh * ow);
                let rem = row % (oh * ow);
                let oy = rem / ow;
                let ox = rem % ow;
                for ci in 0..c {
                    for ky in 0..kh {
                        let iy = (oy * g.stride.0 + ky * g.dilation.0) as isize - g.pad.0 as isize;
                        for kx in 0..kw {
                            let ix =
                                (ox * g.stride.1 + kx * g.dilation.1) as isize - g.pad.1 as isize;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                orow[(ci * kh + ky) * kw + kx] =
                                    xd[((ni * c + ci) * h + iy as usize) * w + ix as usize];
                            }
                        }
                    }
                }
            }
        });
    }
    NdArray::from_vec(&[rows, cols], out)
}

/// col2im scatter-accumulate into a caller-provided **zeroed** buffer
/// (shared with the fused conv/deconv backward kernels, whose column
/// gradients live in the scratch arena; every caller hands a
/// fresh-zeroed allocation, so this never re-clears). Parallel over
/// `(n, c)` output-plane groups: every output pixel accumulates its
/// overlapping patches in the same `(oy, ox, ky, kx)` order the serial
/// loop used, inside one chunk — bit-identical at any thread count.
/// Below the parallel threshold a single chunk runs inline (no pool
/// job).
pub(crate) fn col2im_slice(out: &mut [f32], cols: &[f32], x_dims: &[usize], g: &Conv2dGeom) {
    let (n, c, h, w) = (x_dims[0], x_dims[1], x_dims[2], x_dims[3]);
    let (kh, kw) = g.kernel;
    let (oh, ow) = g.out_hw(h, w);
    let ncols = c * kh * kw;
    assert_eq!(out.len(), n * c * h * w, "col2im output size");
    assert_eq!(cols.len(), n * oh * ow * ncols, "col2im column size");
    let hw = h * w;
    let n_planes = n * c;
    let planes_per_chunk = if cols.len() < PAR_MIN {
        n_planes.max(1)
    } else {
        n_planes.div_ceil(64).max(1)
    };
    parallel::for_each_chunk_mut(out, (planes_per_chunk * hw).max(1), |gi, group| {
        for (lp, plane) in group.chunks_exact_mut(hw).enumerate() {
            let pi = gi * planes_per_chunk + lp;
            let ni = pi / c;
            let ch = pi % c;
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = ((ni * oh + oy) * ow + ox) * ncols;
                    for ky in 0..kh {
                        let iy = (oy * g.stride.0 + ky * g.dilation.0) as isize - g.pad.0 as isize;
                        if iy < 0 || (iy as usize) >= h {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix =
                                (ox * g.stride.1 + kx * g.dilation.1) as isize - g.pad.1 as isize;
                            if ix < 0 || (ix as usize) >= w {
                                continue;
                            }
                            plane[iy as usize * w + ix as usize] +=
                                cols[row + (ch * kh + ky) * kw + kx];
                        }
                    }
                }
            }
        }
    });
}

/// col2im: adjoint of [`im2col`] — scatters column gradients back to
/// the input layout (accumulating where patches overlap).
pub fn col2im(cols: &NdArray, x_dims: &[usize], g: &Conv2dGeom) -> NdArray {
    let (n, c) = (x_dims[0], x_dims[1]);
    let (kh, kw) = g.kernel;
    let (oh, ow) = g.out_hw(x_dims[2], x_dims[3]);
    assert_eq!(cols.dims(), &[n * oh * ow, c * kh * kw]);
    let mut out = vec![0.0f32; x_dims.iter().product()];
    col2im_slice(&mut out, cols.data(), x_dims, g);
    NdArray::from_vec(x_dims, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_broadcast_bias() {
        let x = NdArray::arange(&[2, 3]);
        let b = NdArray::from_slice(&[3], &[10., 20., 30.]);
        let y = add(&x, &b);
        assert_eq!(y.data(), &[10., 21., 32., 13., 24., 35.]);
    }

    #[test]
    fn reduce_to_shape_is_broadcast_adjoint() {
        let g = NdArray::ones(&[2, 3]);
        let r = reduce_to_shape(&g, &Shape::new(&[3]));
        assert_eq!(r.data(), &[2., 2., 2.]);
        let r2 = reduce_to_shape(&g, &Shape::new(&[2, 1]));
        assert_eq!(r2.data(), &[3., 3.]);
        let r3 = reduce_to_shape(&g, &Shape::scalar());
        assert_eq!(r3.item(), 6.0);
    }

    #[test]
    fn sum_mean_max_axis() {
        let a = NdArray::from_slice(&[2, 3], &[1., 5., 3., 4., 2., 6.]);
        assert_eq!(sum_axis(&a, 0, false).data(), &[5., 7., 9.]);
        assert_eq!(sum_axis(&a, 1, false).data(), &[9., 12.]);
        assert_eq!(sum_axis(&a, 1, true).dims(), &[2, 1]);
        assert_eq!(mean_axis(&a, 1, false).data(), &[3., 4.]);
        let (m, arg) = max_axis(&a, 1, false);
        assert_eq!(m.data(), &[5., 6.]);
        assert_eq!(arg, vec![1, 5]);
    }

    #[test]
    fn matmul_small() {
        let a = NdArray::from_slice(&[2, 2], &[1., 2., 3., 4.]);
        let b = NdArray::ones(&[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let a = NdArray::arange(&[3, 3]);
        let mut i = NdArray::zeros(&[3, 3]);
        for d in 0..3 {
            i.set(&[d, d], 1.0);
        }
        assert_eq!(matmul(&a, &i), a);
        assert_eq!(matmul(&i, &a), a);
    }

    #[test]
    fn matmul_rect() {
        // [1,3]x[3,2]
        let a = NdArray::from_slice(&[1, 3], &[1., 2., 3.]);
        let b = NdArray::from_slice(&[3, 2], &[1., 4., 2., 5., 3., 6.]);
        let c = matmul(&a, &b);
        assert_eq!(c.dims(), &[1, 2]);
        assert_eq!(c.data(), &[14., 32.]);
    }

    #[test]
    fn batch_matmul_matches_loop() {
        let a = NdArray::arange(&[2, 2, 3]);
        let b = NdArray::arange(&[2, 3, 2]);
        let c = batch_matmul(&a, &b);
        for i in 0..2 {
            let ai = a.slice_axis(0, i, i + 1).reshape(&[2, 3]);
            let bi = b.slice_axis(0, i, i + 1).reshape(&[3, 2]);
            let ci = c.slice_axis(0, i, i + 1).reshape(&[2, 2]);
            assert_eq!(matmul(&ai, &bi), ci);
        }
    }

    #[test]
    fn matmul_matches_naive_past_the_tiled_cutoff() {
        let mut rng = crate::tensor::Rng::new(77);
        let a = rng.randn(&[70, 50], 1.0);
        let b = rng.randn(&[50, 60], 1.0);
        let got = matmul(&a, &b);
        let want = matmul_naive(&a, &b);
        assert_eq!(got.dims(), want.dims());
        assert!(got.allclose(&want, 1e-4, 1e-4), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn try_out_hw_rejects_degenerate_geometry() {
        // kernel larger than padded input: used to underflow usize
        let g = Conv2dGeom::simple(7, 7);
        assert_eq!(g.try_out_hw(4, 4), None);
        let ok = Conv2dGeom { kernel: (7, 7), stride: (1, 1), pad: (2, 2), dilation: (1, 1) };
        assert_eq!(ok.try_out_hw(4, 4), Some((2, 2)));
        // zero stride / dilation / kernel are degenerate, not panics
        let z = Conv2dGeom { kernel: (2, 2), stride: (0, 1), pad: (0, 0), dilation: (1, 1) };
        assert_eq!(z.try_out_hw(8, 8), None);
        let d = Conv2dGeom { kernel: (2, 2), stride: (1, 1), pad: (0, 0), dilation: (0, 1) };
        assert_eq!(d.try_out_hw(8, 8), None);
        // dilation pushes the effective kernel past the input
        let far = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (0, 0), dilation: (4, 4) };
        assert_eq!(far.try_out_hw(8, 8), None);
    }

    #[test]
    #[should_panic(expected = "convolution geometry invalid")]
    fn out_hw_panics_with_context_on_degenerate_geometry() {
        Conv2dGeom::simple(9, 9).out_hw(2, 2);
    }

    #[test]
    fn conv_geom_output_size() {
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        assert_eq!(g.out_hw(8, 8), (8, 8)); // same padding
        let g2 = Conv2dGeom { kernel: (2, 2), stride: (2, 2), pad: (0, 0), dilation: (1, 1) };
        assert_eq!(g2.out_hw(8, 8), (4, 4));
        let g3 = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (0, 0), dilation: (2, 2) };
        assert_eq!(g3.out_hw(8, 8), (4, 4));
    }

    #[test]
    fn im2col_1x1_is_reshape_transpose() {
        let x = NdArray::arange(&[1, 2, 2, 2]);
        let g = Conv2dGeom::simple(1, 1);
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 2]);
        // row (y,x), col c -> x[0, c, y, x]
        assert_eq!(c.at(&[0, 0]), x.at(&[0, 0, 0, 0]));
        assert_eq!(c.at(&[3, 1]), x.at(&[0, 1, 1, 1]));
    }

    #[test]
    fn im2col_known_values() {
        // 1x1x3x3 input, 2x2 kernel, no pad, stride 1 -> 4 patches
        let x = NdArray::arange(&[1, 1, 3, 3]);
        let g = Conv2dGeom::simple(2, 2);
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 4]);
        assert_eq!(&c.data()[0..4], &[0., 1., 3., 4.]); // top-left patch
        assert_eq!(&c.data()[12..16], &[4., 5., 7., 8.]); // bottom-right patch
    }

    #[test]
    fn im2col_padding_zero_fills() {
        let x = NdArray::ones(&[1, 1, 2, 2]);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let c = im2col(&x, &g);
        assert_eq!(c.dims(), &[4, 9]);
        // top-left patch has 5 zeros (border) + 4 ones
        let row0: f32 = c.data()[0..9].iter().sum();
        assert_eq!(row0, 4.0);
    }

    #[test]
    fn argmax_is_nan_safe() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        // regression: partial_cmp().unwrap() panicked on NaN logits
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN, -1.0]), 2);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0); // first max wins
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn col2im_is_im2col_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> for random-ish x, y
        let x = NdArray::arange(&[2, 2, 4, 4]);
        let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
        let cx = im2col(&x, &g);
        let y = NdArray::arange(cx.dims());
        let lhs: f32 = cx.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let cty = col2im(&y, x.dims(), &g);
        let rhs: f32 = x.data().iter().zip(cty.data()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-5);
    }
}
