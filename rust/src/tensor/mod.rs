//! Tensor substrate: a self-contained n-dimensional array library.
//!
//! This is the storage/compute layer underneath the dynamic-graph engine
//! (the paper's "define-by-run" mode). Arrays are contiguous, row-major
//! `f32` buffers with a *storage dtype* tag: `BF16`/`F16` arrays keep
//! their values rounded to the nearest representable half-precision
//! value on every write, faithfully simulating half-precision storage
//! (the paper §3.3) while computing in f32 — the same "compute in f32,
//! store in half" contract the MXU/TensorCore path uses.
//!
//! Compute splits across three submodules: [`ops`] holds the
//! tensor-level kernels (elementwise, reductions, matmul, the
//! im2col/col2im lowering), [`kernels`] the packed register-tiled GEMM
//! core, fused conv/affine kernels, the per-thread scratch arena, and
//! the runtime-dispatched SIMD microkernel tiers
//! ([`kernels::dispatch`]: scalar / AVX2+FMA / NEON, pinnable via
//! `NNL_ISA`), and [`parallel`] the persistent `NNL_THREADS` worker
//! pool with a determinism contract: results are bit-identical at any
//! thread count (per ISA tier; int8 is bit-identical to scalar at
//! every tier).

pub mod array;
pub mod dtype;
pub mod kernels;
pub mod ops;
pub mod parallel;
pub mod random;
pub mod shape;

pub use array::NdArray;
pub use dtype::DType;
pub use random::Rng;
pub use shape::Shape;
