//! Persistent worker pool for the compute kernels — `std::thread` only
//! (rayon cannot resolve offline), sized by `NNL_THREADS` (default:
//! available cores; `1` disables the pool entirely).
//!
//! ## Determinism contract
//!
//! Every parallel kernel in this crate shards its *output*: work is cut
//! into chunks whose boundaries depend only on the problem shape (never
//! on the thread count), and each output element is computed entirely
//! inside one chunk with the same sequential inner loop the serial
//! kernel runs. Threads only decide *where* a chunk executes, not what
//! it computes — so results are bit-identical for any `NNL_THREADS`
//! value, any [`with_thread_limit`] scope, and any scheduling order.
//! `tests/kernel_parity.rs` enforces this.
//!
//! ## Shape of the pool
//!
//! One global job slot, claimed chunk-by-chunk: the submitting thread
//! publishes a job, participates in draining it, and blocks until every
//! chunk completed. Workers park on a condvar between jobs. If the slot
//! is already busy (several serve workers running parallel kernels at
//! once) or the caller is itself inside a pool chunk, the call simply
//! runs serially — those callers are already parallel across requests,
//! and nested fan-out would only fight over the same cores.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Pool workers resurrected after a panic escaped the per-chunk
/// `catch_unwind` in [`drain`] (e.g. an injected `pool` chaos fault
/// between taking a job and draining it). The pool is a process-wide
/// singleton, so this is a process-wide counter — surfaced through the
/// serving `HEALTH` probe.
static RESTARTS: AtomicU64 = AtomicU64::new(0);

/// How many pool workers supervision has resurrected (see
/// [`RESTARTS`]).
pub fn worker_restarts() -> u64 {
    RESTARTS.load(Ordering::Relaxed)
}

/// Lifetime-erased pointer to the chunk closure of an in-flight job.
/// Only dereferenced between publication and completion of the job,
/// while the submitting stack frame (which owns the closure) is pinned
/// in [`for_each_chunk`] waiting on the `done` counter.
#[derive(Clone, Copy)]
struct RunPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (bound in the type), so shared access
// from any thread is fine; the pointer itself is only dereferenced
// under the claim protocol documented at [`drain`], which guarantees
// the pointee outlives every dereference.
unsafe impl Send for RunPtr {}
// SAFETY: as above — `&RunPtr` only ever yields a `&dyn Fn + Sync`.
unsafe impl Sync for RunPtr {}

/// One published unit of pool work.
struct Job {
    run: RunPtr,
    n_chunks: usize,
    /// Max workers allowed to join (submitter always participates).
    max_workers: usize,
    /// Next chunk index to claim (may overshoot `n_chunks`).
    claimed: AtomicUsize,
    /// Workers that joined this job.
    tickets: AtomicUsize,
    /// Chunks fully executed.
    done: AtomicUsize,
    /// A chunk closure panicked (re-raised on the submitting thread).
    panicked: AtomicBool,
}

struct Shared {
    slot: Mutex<Option<Arc<Job>>>,
    /// Workers wait here for a job to appear in `slot`.
    work: Condvar,
    /// The submitter waits here for `done == n_chunks`.
    done: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// Depth of pool work on this thread (worker chunk or submitter
    /// participation). Non-zero ⇒ nested `for_each_chunk` runs serially.
    static BUSY: Cell<usize> = const { Cell::new(0) };
    /// Per-thread cap on threads per job (see [`with_thread_limit`]).
    static LIMIT: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let threads = std::env::var("NNL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        let shared = Arc::new(Shared {
            slot: Mutex::new(None),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = threads - 1;
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("nnl-worker-{i}"))
                .spawn(move || {
                    // Supervised: chunk panics are caught inside
                    // `drain` and re-raised on the submitter, so the
                    // only way out of `worker_loop` is a panic outside
                    // a chunk (injected chaos, a bug in the claim
                    // protocol). Losing the thread would silently
                    // shrink the pool forever — resurrect it instead.
                    // The submitter drains remaining chunks itself, so
                    // the in-flight job still completes either way.
                    loop {
                        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || worker_loop(&sh),
                        ));
                        if run.is_ok() {
                            break;
                        }
                        BUSY.with(|b| b.set(0));
                        RESTARTS.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .expect("spawning nnl worker thread");
        }
        Pool { shared, workers }
    })
}

/// Pool width: `NNL_THREADS` if set, else available cores (always ≥ 1;
/// the submitting thread counts as one).
pub fn num_threads() -> usize {
    pool().workers + 1
}

/// Run `f` with parallel kernels capped at `n` threads (1 = serial).
/// Results are bit-identical at any cap — this exists for the
/// thread-scaling bench and the determinism tests, not for correctness.
pub fn with_thread_limit<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LIMIT.with(|l| l.set(self.0));
        }
    }
    let prev = LIMIT.with(|l| l.replace(n.max(1)));
    let _restore = Restore(prev);
    f()
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            // poisoning-safe: the slot is an Option<Arc<Job>>, valid
            // at every release point, and a panicked peer must not
            // wedge the whole pool behind a PoisonError
            let mut slot = shared.slot.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(j) = slot.as_ref() {
                    let open = j.claimed.load(Ordering::Relaxed) < j.n_chunks;
                    let joined = open
                        && j.tickets
                            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| {
                                (t < j.max_workers).then_some(t + 1)
                            })
                            .is_ok();
                    if joined {
                        break Arc::clone(j);
                    }
                }
                slot = shared.work.wait(slot).unwrap_or_else(|e| e.into_inner());
            }
        };
        // chaos `pool` point: a panic here unwinds with a ticket taken
        // but no chunk claimed — the job still completes (the
        // submitter drains), and supervision resurrects this thread
        crate::faults::disrupt(crate::faults::Point::PoolDispatch);
        BUSY.with(|b| b.set(b.get() + 1));
        drain(&job);
        BUSY.with(|b| b.set(b.get() - 1));
        if job.done.load(Ordering::Acquire) >= job.n_chunks {
            let _guard = shared.slot.lock().unwrap_or_else(|e| e.into_inner());
            shared.done.notify_all();
        }
    }
}

/// Claim and execute chunks of `job` until none remain.
fn drain(job: &Job) {
    loop {
        let i = job.claimed.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_chunks {
            return;
        }
        // SAFETY: the pointer may be dereferenced only *after* a
        // successful claim: chunk i is now claimed-but-not-done, so
        // `done < n_chunks` holds until we finish it — the submitter is
        // pinned in `for_each_chunk`'s completion wait and the closure
        // behind the pointer (owned by that stack frame) is alive.
        // (Before a claim the job may already be finished and the
        // submitter gone; `loom_pool_late_joiner_claims_nothing` in
        // tests/loom_models.rs model-checks exactly this rule.)
        let f = unsafe { &*job.run.0 };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
        if ok.is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        job.done.fetch_add(1, Ordering::Release);
    }
}

/// Execute `f(0), f(1), …, f(n_chunks - 1)`, spread over the pool.
/// Chunks are claimed dynamically but each runs exactly once; the call
/// returns only after every chunk finished. Falls back to a plain
/// serial loop when the pool is width 1, a [`with_thread_limit`] cap
/// says so, the job slot is already busy, or the caller is itself a
/// pool chunk (nested parallelism).
pub fn for_each_chunk(n_chunks: usize, f: impl Fn(usize) + Sync) {
    if n_chunks == 0 {
        return;
    }
    let limit = LIMIT.with(|l| l.get());
    let pool = pool();
    let nested = BUSY.with(|b| b.get()) > 0;
    if n_chunks == 1 || limit <= 1 || pool.workers == 0 || nested {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    let obj: &(dyn Fn(usize) + Sync) = &f;
    let job = Arc::new(Job {
        run: RunPtr(obj as *const _),
        n_chunks,
        max_workers: (limit - 1).min(pool.workers),
        claimed: AtomicUsize::new(0),
        tickets: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
    });
    {
        let mut slot = pool.shared.slot.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_some() {
            // another thread's job is in flight: run serially rather
            // than queueing (callers here are already parallel)
            drop(slot);
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
        *slot = Some(Arc::clone(&job));
        pool.shared.work.notify_all();
    }
    BUSY.with(|b| b.set(b.get() + 1));
    drain(&job);
    BUSY.with(|b| b.set(b.get() - 1));
    let mut slot = pool.shared.slot.lock().unwrap_or_else(|e| e.into_inner());
    while job.done.load(Ordering::Acquire) < n_chunks {
        slot = pool.shared.done.wait(slot).unwrap_or_else(|e| e.into_inner());
    }
    *slot = None;
    drop(slot);
    if job.panicked.load(Ordering::Relaxed) {
        panic!("a parallel kernel chunk panicked (see worker backtrace above)");
    }
}

/// Shared-to-mutable bridge for disjoint chunk writes.
struct SendPtr<T>(*mut T);
// SAFETY: the wrapped pointer is only materialised into slices inside
// [`for_each_chunk_mut`], whose chunk layout makes every derived slice
// disjoint — so handing the pointer to another thread never creates
// aliasing mutable access. `T: Send` carries the element requirement.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — a `&SendPtr` exposes no operations at all; all
// access goes through the disjoint-slice construction below.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Split `data` into consecutive chunks of `chunk_len` (last one may be
/// shorter) and run `f(chunk_index, chunk)` for each, in parallel. The
/// chunk layout depends only on `data.len()` and `chunk_len`, never on
/// the thread count — the determinism contract above.
pub fn for_each_chunk_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if data.is_empty() {
        return;
    }
    assert!(chunk_len > 0, "chunk_len must be non-zero");
    let len = data.len();
    let n_chunks = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    for_each_chunk(n_chunks, |i| {
        let start = i * chunk_len;
        let end = (start + chunk_len).min(len);
        // SAFETY: chunk i covers exactly [i·chunk_len, min((i+1)·chunk_len,
        // len)) — chunks are disjoint by construction and stay inside the
        // original `&mut [T]`, which outlives this call because
        // `for_each_chunk` returns only after every chunk completed. Each
        // chunk index is executed exactly once (pool claim protocol), so
        // no two live slices ever alias.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(i, chunk);
    });
}

/// The chunk range `[i * chunk_len, min((i+1) * chunk_len, n))` —
/// the read-only twin of [`for_each_chunk_mut`]'s layout, for kernels
/// that shard work over an index space instead of an output slice.
pub fn chunk_range(n: usize, chunk_len: usize, i: usize) -> Range<usize> {
    let start = i * chunk_len;
    start..(start + chunk_len).min(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_chunk_runs_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        for_each_chunk(counts.len(), |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunk_mut_partitions_exactly() {
        let mut data = vec![0u32; 1003];
        for_each_chunk_mut(&mut data, 64, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + i as u32 % 2;
            }
        });
        // every element written exactly once
        assert!(data.iter().all(|&v| v == 1 || v == 2));
        assert_eq!(data.iter().filter(|&&v| v > 0).count(), 1003);
    }

    #[test]
    fn nested_calls_serialize_instead_of_deadlocking() {
        let outer: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        for_each_chunk(outer.len(), |i| {
            let inner: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
            for_each_chunk(inner.len(), |j| {
                inner[j].fetch_add(1, Ordering::Relaxed);
            });
            assert!(inner.iter().all(|c| c.load(Ordering::Relaxed) == 1));
            outer[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(outer.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn thread_limit_forces_serial() {
        with_thread_limit(1, || {
            let on_main = std::thread::current().id();
            for_each_chunk(32, |_| {
                assert_eq!(std::thread::current().id(), on_main);
            });
        });
        assert!(num_threads() >= 1);
    }

    #[test]
    fn chunk_range_layout() {
        assert_eq!(chunk_range(10, 4, 0), 0..4);
        assert_eq!(chunk_range(10, 4, 2), 8..10);
    }
}
