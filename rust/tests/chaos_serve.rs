//! Deterministic chaos suite (`cargo test --features chaos --test
//! chaos_serve`): armed fault schedules storm the serving stack and
//! the tests assert the fault-tolerance invariants — every admitted
//! request gets exactly one typed reply, no worker stays dead, hot
//! swap never fails a request, shutdown drains cleanly, and corrupt
//! artifacts never poison the registry. `NNL_CHAOS_SEED` picks the
//! schedule; CI pins several seeds. Tests share the process-global
//! schedule, so they serialize on a gate.
#![cfg(feature = "chaos")]

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use nnl::faults::{self, Schedule};
use nnl::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use nnl::nnp::{CompiledNet, InferencePlan};
use nnl::serve::net::{NetClient, NetConfig, NetServer, Registry};
use nnl::serve::{RetryPolicy, ServeConfig, ServeError, Server};
use nnl::tensor::{NdArray, Rng};

static GATE: Mutex<()> = Mutex::new(());

/// One test at a time: the armed schedule is process-global.
fn serial() -> MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn chaos_seed() -> u64 {
    std::env::var("NNL_CHAOS_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(42)
}

/// Injected panics are the *point* of this suite — keep their default
/// backtrace spam out of the test output, let real panics through.
fn quiet_chaos_panics() {
    static QUIET: std::sync::Once = std::sync::Once::new();
    QUIET.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let p = info.payload();
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.starts_with("chaos:") {
                default(info);
            }
        }));
    });
}

fn scaled_plan(scale: f32) -> Arc<CompiledNet> {
    let net = NetworkDef {
        name: "affine".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
        outputs: vec!["y".into()],
        layers: vec![Layer {
            name: "fc".into(),
            op: Op::Affine,
            inputs: vec!["x".into()],
            params: vec!["W".into()],
            outputs: vec!["y".into()],
        }],
    };
    let mut params = HashMap::new();
    params.insert("W".to_string(), NdArray::from_slice(&[2, 3], &[scale, 0., 0., 0., scale, 0.]));
    Arc::new(CompiledNet::compile(&net, &params).unwrap())
}

#[test]
fn every_admitted_request_gets_exactly_one_typed_reply_under_panics() {
    let _g = serial();
    quiet_chaos_panics();
    let inner = scaled_plan(2.0);

    // reference outputs computed before any chaos is armed
    let xs: Vec<NdArray> =
        (0..200).map(|i| NdArray::from_slice(&[1, 2], &[i as f32, 1.0])).collect();
    let want: Vec<Vec<f32>> = xs
        .iter()
        .map(|x| inner.execute_positional(std::slice::from_ref(x)).unwrap()[0].data().to_vec())
        .collect();

    // panics both inside the per-request boundary (exec → typed
    // Internal for that request) and outside it (worker → reply guard
    // answers the held batch, supervision restarts the thread)
    faults::install(
        Schedule::parse("exec:panic:0.12,worker:panic:0.06,admit:delay:0.05:2", chaos_seed())
            .unwrap(),
    );
    let server = Server::start(
        Arc::clone(&inner),
        ServeConfig {
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
        },
    );
    let rxs: Vec<_> = xs
        .iter()
        .map(|x| server.submit(vec![x.clone()]).expect("queue deep enough to admit all"))
        .collect();
    let (mut ok, mut internal) = (0usize, 0usize);
    for (rx, want) in rxs.into_iter().zip(&want) {
        match rx.recv().expect("exactly one typed reply per admitted request") {
            Ok(outs) => {
                assert_eq!(outs[0].data(), &want[..], "a successful reply must be exact");
                ok += 1;
            }
            Err(ServeError::Internal(_)) => internal += 1,
            Err(other) => panic!("unexpected error kind under panic chaos: {other}"),
        }
    }
    assert_eq!(ok + internal, 200, "no request may vanish or be answered twice");

    // disarm: the same pool serves again, bit-identical
    faults::clear();
    let out = server.infer(vec![xs[7].clone()]).unwrap();
    assert_eq!(out[0].data(), &want[7][..], "post-chaos output diverged");
    assert_eq!(server.alive_workers(), 2, "no worker stays dead");
    let stats = server.shutdown();
    assert!(
        stats.panics_caught + stats.worker_restarts > 0,
        "at these rates over 200 requests the schedule must have fired"
    );
    assert_eq!(stats.requests, 201);
}

#[test]
fn tcp_requests_converge_with_retries_across_transport_chaos_and_hot_swap() {
    let _g = serial();
    quiet_chaos_panics();
    let seed = chaos_seed();
    let registry = Arc::new(Registry::new(ServeConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 128,
    }));
    registry.deploy("m", scaled_plan(3.0), "f32");
    let server =
        NetServer::bind("127.0.0.1:0", Arc::clone(&registry), NetConfig::default()).unwrap();
    let addr = server.local_addr();

    // transient transport damage only: truncated reply frames, reset
    // reads, delayed writes — exactly what the client retry absorbs
    faults::install(
        Schedule::parse("net.write:corrupt:0.15,net.read:ioerr:0.03,net.write:delay:0.05:2", seed)
            .unwrap(),
    );
    let policy = RetryPolicy {
        max_retries: 12,
        base: Duration::from_millis(1),
        cap: Duration::from_millis(5),
        seed,
    };
    let mut cli = NetClient::connect(addr).unwrap();
    let mut total_retries = 0usize;
    for i in 0..40 {
        if i == 20 {
            // hot swap to identical weights mid-chaos: the swap itself
            // must never fail a request or change an answer
            let v = registry.deploy("m", scaled_plan(3.0), "f32");
            assert_eq!(v, 2);
        }
        let x = NdArray::from_slice(&[1, 2], &[i as f32, 0.0]);
        let (outs, retries) = cli
            .infer_with_retry("m", std::slice::from_ref(&x), &policy)
            .expect("every request must converge to Ok under transient-only chaos");
        assert!(
            (outs[0].data()[0] - 3.0 * i as f32).abs() < 1e-4,
            "request {i} got a wrong value: {}",
            outs[0].data()[0]
        );
        total_retries += retries;
    }
    faults::clear();
    assert!(total_retries > 0, "transport chaos at these rates must cost retries");

    // the registry is healthy once the dust settles
    let mut probe = NetClient::connect(addr).unwrap();
    let h = probe.health().unwrap();
    assert_eq!(h.get("ready").as_bool(), Some(true));
    assert_eq!(h.get("models").get("m").get("version").as_usize(), Some(2));
    server.shutdown();
}

#[test]
fn panic_storm_shutdown_drains_every_request_then_recovers() {
    let _g = serial();
    quiet_chaos_panics();
    let inner = scaled_plan(1.5);
    let x_ref = NdArray::from_slice(&[1, 2], &[4.0, 1.0]);
    let want = inner.execute_positional(std::slice::from_ref(&x_ref)).unwrap()[0].data().to_vec();

    faults::install(
        Schedule::parse("exec:panic:0.3,worker:panic:0.2,pool:panic:0.05", chaos_seed()).unwrap(),
    );
    let server = Server::start(
        Arc::clone(&inner),
        ServeConfig {
            workers: 3,
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
        },
    );
    let rxs: Vec<_> = (0..30)
        .map(|i| {
            let x = NdArray::from_slice(&[1, 2], &[i as f32, 1.0]);
            server.submit(vec![x]).expect("admission")
        })
        .collect();
    // shutdown with the storm still armed: the drain itself is under
    // fire, and must still answer absolutely everything
    let stats = server.shutdown();
    for rx in rxs {
        let reply = rx.recv().expect("clean shutdown must not drop an admitted request");
        assert!(
            matches!(reply, Ok(_) | Err(ServeError::Internal(_))),
            "non-typed outcome during storm drain: {reply:?}"
        );
    }
    assert_eq!(stats.requests, 30);

    // a fresh pool on the same plan, chaos disarmed, is pristine
    faults::clear();
    let server = Server::start(inner, ServeConfig::default());
    let out = server.infer(vec![x_ref]).unwrap();
    assert_eq!(out[0].data(), &want[..], "recovery output diverged");
    server.shutdown();
}

#[test]
fn corrupt_artifacts_never_poison_the_registry() {
    let _g = serial();
    quiet_chaos_panics();
    let seed = chaos_seed();
    let registry = Registry::new(ServeConfig::default());
    let (net, params) = nnl::models::zoo::export_eval("mlp", 3);
    let pairs: Vec<(String, NdArray)> = params.clone().into_iter().collect();
    let image = nnl::converters::nnb::to_nnb(&net, &pairs);

    // a decode that fails outright is a typed rejection, nothing swaps
    faults::install(Schedule::parse("decode:ioerr:1.0", seed).unwrap());
    let err = registry.deploy_artifact("mlp", &image).unwrap_err();
    assert!(matches!(err, ServeError::InvalidRequest(_)), "{err}");
    assert!(!registry.contains("mlp"), "a failed deploy must leave no trace");

    // a bit-flipped image: where the flip lands depends on the seed,
    // but the outcome must be *typed* either way — a rejection that
    // leaves the registry untouched, or a clean deploy of an image
    // that still decodes and verifies
    faults::install(Schedule::parse("decode:corrupt:1.0", seed).unwrap());
    match registry.deploy_artifact("mlp", &image) {
        Err(_) => assert!(!registry.contains("mlp")),
        Ok((v, _)) => assert_eq!(v, 1),
    }

    // chaos off: the pristine image deploys and serves exactly what an
    // uncontaminated registry serves
    faults::clear();
    let before = registry.version("mlp").unwrap_or(0);
    let (v, kind) = registry.deploy_artifact("mlp", &image).unwrap();
    assert_eq!(kind, "f32");
    assert_eq!(v, before + 1);
    let clean = Registry::new(ServeConfig::default());
    clean.deploy_artifact("ref", &image).unwrap();
    let x = Rng::new(5).rand(&[1, 64], -1.0, 1.0);
    let got = registry.infer("mlp", vec![x.clone()]).unwrap();
    let want = clean.infer("ref", vec![x]).unwrap();
    assert_eq!(got[0].data(), want[0].data(), "post-chaos deploy must serve clean weights");
}
