//! Serving front-end integration: the TCP binary protocol and JSON
//! fallback end to end, a multi-model registry hosting f32 and int8
//! plans in one server process, atomic hot reload under multi-threaded
//! live load (zero failed requests across N swaps), registry
//! add/remove/lookup races, and typed load shedding. This is the
//! suite CI runs explicitly under `NNL_THREADS=1`.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use nnl::models::zoo;
use nnl::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use nnl::nnp::{CompiledNet, InferencePlan};
use nnl::quant::{quantize_net, QuantConfig};
use nnl::serve::net::{NetClient, NetConfig, NetServer, Registry};
use nnl::serve::{ServeConfig, ServeError};
use nnl::tensor::{NdArray, Rng};

/// `y = x @ W` on a `[1, 2] -> [1, 3]` affine — cheap, batchable, and
/// with weights distinguishable per model version.
fn affine_plan(w: &[f32]) -> Arc<CompiledNet> {
    let net = NetworkDef {
        name: "affine".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
        outputs: vec!["y".into()],
        layers: vec![Layer {
            name: "fc".into(),
            op: Op::Affine,
            inputs: vec!["x".into()],
            params: vec!["W".into()],
            outputs: vec!["y".into()],
        }],
    };
    let mut params = HashMap::new();
    params.insert("W".to_string(), NdArray::from_slice(&[2, 3], w));
    Arc::new(CompiledNet::compile(&net, &params).unwrap())
}

/// A scaled identity-ish weight matrix: output[0] = scale * input[0],
/// so a response identifies which deployed version served it.
fn scaled_plan(scale: f32) -> Arc<CompiledNet> {
    affine_plan(&[scale, 0., 0., 0., scale, 0.])
}

fn bind_test_server(registry: Arc<Registry>) -> NetServer {
    NetServer::bind("127.0.0.1:0", registry, NetConfig::default())
        .expect("binding an ephemeral loopback port")
}

#[test]
fn binary_protocol_serves_f32_and_int8_models_in_one_process() {
    // one server process, two models: the zoo MLP as f32 and the same
    // net quantized to int8 (the ISSUE acceptance scenario)
    let (net, params) = zoo::export_eval("mlp", 21);
    let plan = Arc::new(CompiledNet::compile(&net, &params).unwrap());
    let mut rng = Rng::new(4);
    let samples: Vec<Vec<NdArray>> = (0..16).map(|_| vec![rng.rand(&[1, 64], -1.0, 1.0)]).collect();
    let (_, qnet) = quantize_net(&net, &params, &samples, &QuantConfig::default()).unwrap();
    let qnet = Arc::new(qnet);

    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("mlp_f32", Arc::clone(&plan), "f32");
    registry.deploy("mlp_int8", Arc::clone(&qnet), "int8");
    let server = bind_test_server(Arc::clone(&registry));

    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.ping().unwrap();

    // LIST sees both models with their kinds and input signatures
    let list = client.list().unwrap();
    let rows = list.as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    let kinds: Vec<(&str, &str)> = rows
        .iter()
        .map(|r| (r.get("name").as_str().unwrap(), r.get("kind").as_str().unwrap()))
        .collect();
    assert_eq!(kinds, vec![("mlp_f32", "f32"), ("mlp_int8", "int8")]);
    let dims = rows[0].get("inputs").as_arr().unwrap()[0].get("dims").usize_arr();
    assert_eq!(dims, Some(vec![1, 64]));

    // wire INFER matches direct plan execution exactly, per backend
    let x = rng.rand(&[1, 64], -1.0, 1.0);
    let got = client.infer("mlp_f32", std::slice::from_ref(&x)).unwrap();
    let want = plan.execute_positional(std::slice::from_ref(&x)).unwrap();
    assert_eq!(got[0].dims(), want[0].dims());
    assert_eq!(got[0].data(), want[0].data());

    let got_q = client.infer("mlp_int8", std::slice::from_ref(&x)).unwrap();
    let want_q = qnet.execute_positional(std::slice::from_ref(&x)).unwrap();
    assert_eq!(got_q[0].data(), want_q[0].data());

    // STATS reports both models with live counters
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("mlp_f32").get("requests").as_usize(), Some(1));
    assert_eq!(stats.get("mlp_f32").get("kind").as_str(), Some("f32"));
    assert_eq!(stats.get("mlp_int8").get("kind").as_str(), Some("int8"));
    assert!(stats.get("mlp_f32").get("p50_ms").as_f64().unwrap() > 0.0);

    // typed miss for an unknown model
    let err = client.infer("ghost", std::slice::from_ref(&x)).unwrap_err();
    assert!(matches!(err, ServeError::NoSuchModel(_)), "{err}");
    server.shutdown();
}

#[test]
fn hot_swap_under_live_load_never_fails_a_request() {
    // 4 client threads hammer one model over TCP while the main thread
    // hot-swaps the plan 5 times; every reply must be a correct output
    // of SOME deployed version — never an error, never a gap
    const SWAPS: u64 = 5;
    const CLIENTS: usize = 4;
    let scales: Vec<f32> = (0..=SWAPS).map(|v| (v + 1) as f32).collect();

    let registry = Arc::new(Registry::new(ServeConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 256,
    }));
    registry.deploy("m", scaled_plan(scales[0]), "f32");
    let server = bind_test_server(Arc::clone(&registry));
    let addr = server.local_addr();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let valid: Arc<Vec<f32>> = Arc::new(scales.clone());
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let stop = Arc::clone(&stop);
            let valid = Arc::clone(&valid);
            std::thread::spawn(move || {
                let mut cli = NetClient::connect(addr).expect("client connect");
                let mut served = 0u64;
                let mut i = 0f32;
                while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                    i += 1.0;
                    let probe = i + c as f32 / 8.0;
                    let x = NdArray::from_slice(&[1, 2], &[probe, 0.0]);
                    let out = cli
                        .infer("m", std::slice::from_ref(&x))
                        .expect("no request may fail across a hot swap");
                    let y = out[0].data()[0];
                    assert!(
                        valid.iter().any(|s| (y - s * probe).abs() < 1e-4),
                        "response {y} matches no deployed version for input {probe}"
                    );
                    served += 1;
                }
                served
            })
        })
        .collect();

    // let traffic establish, then swap repeatedly under load
    std::thread::sleep(Duration::from_millis(30));
    for v in 1..=SWAPS {
        let version = registry.deploy("m", scaled_plan(scales[v as usize]), "f32");
        assert_eq!(version, v + 1);
        std::thread::sleep(Duration::from_millis(20));
    }
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let total: u64 = clients.into_iter().map(|h| h.join().expect("client thread")).sum();
    assert!(total > 0, "load generator never got a request through");

    // after the dust settles, a fresh request must see the final version
    let mut cli = NetClient::connect(addr).unwrap();
    let x = NdArray::from_slice(&[1, 2], &[1.0, 0.0]);
    let y = cli.infer("m", std::slice::from_ref(&x)).unwrap()[0].data()[0];
    let last = *scales.last().unwrap();
    assert!((y - last).abs() < 1e-4, "fresh request saw {y}, want {last}");

    let stats = cli.stats().unwrap();
    assert_eq!(stats.get("m").get("swaps").as_usize(), Some(SWAPS as usize));
    assert_eq!(stats.get("m").get("errors").as_usize(), Some(0));
    assert_eq!(stats.get("m").get("version").as_usize(), Some((SWAPS + 1) as usize));
    assert!(stats.get("m").get("requests").as_usize().unwrap() as u64 >= total);
    server.shutdown();
}

#[test]
fn registry_add_remove_lookup_races_stay_typed() {
    // threads concurrently deploy, remove, and infer against the same
    // names: every observable outcome must be a success or a typed
    // error — no panics, no hangs
    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("stable", scaled_plan(1.0), "f32");

    let churn = {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || {
            for round in 0..20 {
                registry.deploy("flicker", scaled_plan(round as f32 + 1.0), "f32");
                std::thread::sleep(Duration::from_millis(1));
                registry.remove("flicker");
            }
        })
    };
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let registry = Arc::clone(&registry);
            std::thread::spawn(move || {
                let x = NdArray::from_slice(&[1, 2], &[2.0, 0.0]);
                let (mut hits, mut misses) = (0u32, 0u32);
                for _ in 0..200 {
                    match registry.infer("flicker", vec![x.clone()]) {
                        Ok(out) => {
                            assert_eq!(out[0].dims(), &[1, 3]);
                            hits += 1;
                        }
                        Err(ServeError::NoSuchModel(name)) => {
                            assert_eq!(name, "flicker");
                            misses += 1;
                        }
                        Err(other) => panic!("unexpected error under churn: {other}"),
                    }
                    // the stable model must never be disturbed by churn
                    let y = registry.infer("stable", vec![x.clone()]).unwrap();
                    assert_eq!(y[0].data()[0], 2.0);
                }
                (hits, misses)
            })
        })
        .collect();
    churn.join().expect("churn thread");
    let (mut hits, mut misses) = (0u32, 0u32);
    for h in readers {
        let (a, b) = h.join().expect("reader thread");
        hits += a;
        misses += b;
    }
    // every probe resolved to exactly one typed outcome
    assert_eq!(hits + misses, 600);
    // after the churn ends, the removal is the deterministic state
    assert!(!registry.contains("flicker"));
    let err = registry.infer("flicker", vec![NdArray::zeros(&[1, 2])]).unwrap_err();
    assert_eq!(err, ServeError::NoSuchModel("flicker".to_string()));
    assert!(registry.contains("stable"));
}

/// An [`InferencePlan`] that sleeps per request — external impls of
/// the public trait must work (defaulted `peak_arena_bytes`), and a
/// slow plan is how the wire-level shed path is forced
/// deterministically.
struct SlowPlan {
    inner: Arc<CompiledNet>,
    delay: Duration,
}

impl InferencePlan for SlowPlan {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn inputs(&self) -> &[TensorDef] {
        self.inner.inputs()
    }
    fn outputs(&self) -> &[String] {
        self.inner.outputs()
    }
    fn n_steps(&self) -> usize {
        self.inner.n_steps()
    }
    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        self.inner.check_inputs(inputs)
    }
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        std::thread::sleep(self.delay);
        self.inner.execute_positional(inputs)
    }
    fn batch_invariant(&self) -> bool {
        false
    }
}

#[test]
fn full_queue_sheds_over_the_wire_with_typed_replies() {
    let registry = Arc::new(Registry::new(ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_cap: 2,
    }));
    let slow = SlowPlan { inner: scaled_plan(1.0), delay: Duration::from_millis(60) };
    registry.deploy("slow", Arc::new(slow), "f32");
    let server = bind_test_server(Arc::clone(&registry));
    let addr = server.local_addr();

    // a burst of concurrent connections: the 2-slot queue + 1 worker
    // must shed some and answer the rest correctly
    let handles: Vec<_> = (0..10)
        .map(|i| {
            std::thread::spawn(move || {
                let mut cli = NetClient::connect(addr).expect("connect");
                let x = NdArray::from_slice(&[1, 2], &[i as f32, 0.0]);
                match cli.infer("slow", std::slice::from_ref(&x)) {
                    Ok(out) => {
                        assert_eq!(out[0].data()[0], i as f32);
                        (1u32, 0u32)
                    }
                    Err(ServeError::Overloaded { .. }) => (0, 1),
                    Err(other) => panic!("expected Overloaded, got: {other}"),
                }
            })
        })
        .collect();
    let (mut ok, mut shed) = (0u32, 0u32);
    for h in handles {
        let (a, b) = h.join().expect("burst client");
        ok += a;
        shed += b;
    }
    assert_eq!(ok + shed, 10);
    assert!(shed >= 1, "a 2-slot queue under a 10-way burst must shed");
    assert!(ok >= 1, "admission control must not starve everything");

    let mut cli = NetClient::connect(addr).unwrap();
    let stats = cli.stats().unwrap();
    assert_eq!(stats.get("slow").get("shed").as_usize(), Some(shed as usize));
    assert_eq!(stats.get("slow").get("queue_cap").as_usize(), Some(2));
    server.shutdown();
}

#[test]
fn json_fallback_speaks_whole_sessions_over_tcp() {
    use std::io::{BufRead, BufReader, Write};

    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("m", scaled_plan(3.0), "f32");
    let server = bind_test_server(Arc::clone(&registry));

    // a raw socket speaking newline-delimited JSON — no NetClient
    let stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut write = stream;
    let mut ask = |req: &str| -> String {
        write.write_all(req.as_bytes()).unwrap();
        write.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    };

    let line = ask(r#"{"verb":"infer","model":"m","inputs":[{"dims":[1,2],"data":[2.0,0.0]}]}"#);
    assert!(line.contains("\"ok\":true"), "{line}");
    assert!(line.contains('6'), "3.0 * 2.0 must appear in {line}");

    let line = ask(r#"{"verb":"list"}"#);
    assert!(line.contains("\"m\""), "{line}");

    let line = ask(r#"{"verb":"infer","model":"ghost","inputs":[]}"#);
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("no_such_model"), "{line}");

    // hostile garbage gets a typed protocol error, not a dropped conn
    let line = ask(r#"{"verb":"infer","model":"m","inputs":[{"dims":[1,2],"data":[1.0]}]}"#);
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("protocol"), "{line}");

    // the session keeps working after errors
    let line = ask(r#"{"verb":"ping"}"#);
    assert!(line.contains("\"ok\":true"), "{line}");
    server.shutdown();
}

#[test]
fn wire_deploy_and_undeploy_roundtrip() {
    // DEPLOY an NNB1 image over the wire, infer against it, swap it
    // with a second DEPLOY (version bumps), then UNDEPLOY
    let registry = Arc::new(Registry::new(ServeConfig::default()));
    let server = bind_test_server(Arc::clone(&registry));
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    // pin the connection to binary mode before the first DEPLOY frame:
    // mode is sniffed from the first byte, and a DEPLOY frame's length
    // prefix depends on the artifact size
    client.ping().unwrap();

    let (net, params) = zoo::export_eval("mlp", 33);
    let image = nnl::converters::nnb::to_nnb(&net, &params.into_iter().collect::<Vec<_>>());
    let (v1, kind) = client.deploy("wired", &image).unwrap();
    assert_eq!((v1, kind.as_str()), (1, "f32"));

    let mut rng = Rng::new(8);
    let x = rng.rand(&[1, 64], -1.0, 1.0);
    let out = client.infer("wired", std::slice::from_ref(&x)).unwrap();
    assert_eq!(out[0].dims(), &[1, 10]);

    let (v2, _) = client.deploy("wired", &image).unwrap();
    assert_eq!(v2, 2, "re-deploy must hot-swap, not reset");

    client.undeploy("wired").unwrap();
    let err = client.infer("wired", std::slice::from_ref(&x)).unwrap_err();
    assert!(matches!(err, ServeError::NoSuchModel(_)), "{err}");
    // garbage images are rejected with a typed protocol error
    let err = client.deploy("bad", b"not an artifact").unwrap_err();
    assert!(matches!(err, ServeError::Protocol(_)), "{err}");
    server.shutdown();
}
