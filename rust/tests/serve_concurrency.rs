//! Concurrent-serving correctness: one `CompiledNet` shared by many
//! threads must produce outputs bit-identical to the sequential
//! interpreter, and micro-batched serving must equal per-example
//! execution. This is the serve smoke test CI runs explicitly.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use nnl::models::zoo;
use nnl::nnp::{interpreter, CompiledNet};
use nnl::serve::{ServeConfig, Server};
use nnl::tensor::{NdArray, Rng};

#[test]
fn shared_plan_across_threads_is_bit_identical() {
    // lenet exercises conv / pool / affine through the plan
    let (net, params) = zoo::export_eval("lenet", 41);
    let plan = Arc::new(CompiledNet::compile(&net, &params).unwrap());
    let mut rng = Rng::new(5);
    let inputs: Vec<NdArray> = (0..6).map(|_| rng.rand(&[1, 1, 28, 28], -1.0, 1.0)).collect();

    // sequential reference through the one-shot interpreter
    let reference: Vec<NdArray> = inputs
        .iter()
        .map(|x| {
            let mut m = HashMap::new();
            m.insert("x".to_string(), x.clone());
            interpreter::run(&net, &m, &params).unwrap().remove(0)
        })
        .collect();

    let mut handles = Vec::new();
    for _ in 0..4 {
        let plan = Arc::clone(&plan);
        let inputs = inputs.clone();
        handles.push(std::thread::spawn(move || {
            inputs
                .iter()
                .map(|x| plan.execute_positional(std::slice::from_ref(x)).unwrap().remove(0))
                .collect::<Vec<NdArray>>()
        }));
    }
    for h in handles {
        let outs = h.join().expect("worker thread panicked");
        assert_eq!(outs.len(), reference.len());
        for (o, r) in outs.iter().zip(&reference) {
            assert_eq!(o.dims(), r.dims());
            assert_eq!(o.data(), r.data(), "thread output diverged from interpreter");
        }
    }
}

#[test]
fn microbatched_serving_equals_per_example_execution() {
    let (net, params) = zoo::export_eval("mlp", 42);
    let plan = Arc::new(CompiledNet::compile(&net, &params).unwrap());
    let server = Server::start(
        Arc::clone(&plan),
        ServeConfig { workers: 2, max_batch: 8, max_wait: Duration::from_millis(20), queue_cap: 0 },
    );
    assert!(server.batched(), "mlp must be micro-batchable");

    let mut rng = Rng::new(9);
    let inputs: Vec<NdArray> = (0..24).map(|_| rng.rand(&[1, 64], -1.0, 1.0)).collect();
    let rxs: Vec<_> = inputs.iter().map(|x| server.submit(vec![x.clone()]).unwrap()).collect();
    for (x, rx) in inputs.iter().zip(rxs) {
        let got = rx.recv().unwrap().unwrap();
        let want = plan.execute_positional(std::slice::from_ref(x)).unwrap();
        assert_eq!(got[0].dims(), want[0].dims());
        assert_eq!(got[0].data(), want[0].data(), "batched row diverged from solo run");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 24);
    assert_eq!(stats.rows, 24);
    assert_eq!(stats.errors, 0);
}

#[test]
fn concurrent_clients_one_server() {
    let (net, params) = zoo::export_eval("lenet", 43);
    let plan = Arc::new(CompiledNet::compile(&net, &params).unwrap());
    let server = Server::start(
        Arc::clone(&plan),
        ServeConfig { workers: 4, max_batch: 8, max_wait: Duration::from_millis(5), queue_cap: 0 },
    );
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let client = server.client();
        let plan = Arc::clone(&plan);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for _ in 0..8 {
                let x = rng.rand(&[1, 1, 28, 28], -1.0, 1.0);
                let got = client.infer(vec![x.clone()]).unwrap();
                let want = plan.execute_positional(&[x]).unwrap();
                assert_eq!(got[0].data(), want[0].data());
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 32);
    assert_eq!(stats.errors, 0);
}
