//! Fault-tolerance integration (no chaos feature required): panic
//! isolation at the worker boundary, deadline semantics in and out of
//! micro-batches, frame/line caps on both wire protocols, connection-
//! drop cleanup, client retry eligibility, and the HEALTH verb. The
//! deterministic-chaos storms live in `tests/chaos_serve.rs` behind
//! `--features chaos`; this suite must pass in every build.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nnl::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use nnl::nnp::{CompiledNet, InferencePlan};
use nnl::serve::net::{NetClient, NetConfig, NetServer, Registry, MAX_FRAME, PROTO_VERSION};
use nnl::serve::{RetryPolicy, ServeConfig, ServeError, Server};
use nnl::tensor::{parallel, NdArray, Rng};

/// `y = x @ W` on a `[1, 2] -> [1, 3]` affine — cheap and batchable.
fn affine_plan(w: &[f32]) -> Arc<CompiledNet> {
    let net = NetworkDef {
        name: "affine".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
        outputs: vec!["y".into()],
        layers: vec![Layer {
            name: "fc".into(),
            op: Op::Affine,
            inputs: vec!["x".into()],
            params: vec!["W".into()],
            outputs: vec!["y".into()],
        }],
    };
    let mut params = HashMap::new();
    params.insert("W".to_string(), NdArray::from_slice(&[2, 3], w));
    Arc::new(CompiledNet::compile(&net, &params).unwrap())
}

fn scaled_plan(scale: f32) -> Arc<CompiledNet> {
    affine_plan(&[scale, 0., 0., 0., scale, 0.])
}

/// Delegates to a compiled plan but panics when a request's first
/// input value crosses the sentinel — a deterministic "bug" for
/// exercising the per-request isolation boundary.
struct PanicPlan {
    inner: Arc<CompiledNet>,
    sentinel: f32,
}

impl InferencePlan for PanicPlan {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn inputs(&self) -> &[TensorDef] {
        self.inner.inputs()
    }
    fn outputs(&self) -> &[String] {
        self.inner.outputs()
    }
    fn n_steps(&self) -> usize {
        self.inner.n_steps()
    }
    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        self.inner.check_inputs(inputs)
    }
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        if inputs[0].data()[0] >= self.sentinel {
            panic!("poisoned request hit the sentinel");
        }
        self.inner.execute_positional(inputs)
    }
    fn batch_invariant(&self) -> bool {
        false
    }
}

/// Delegates to a compiled plan after a sleep, preserving
/// batch-invariance — how a worker is kept deterministically busy.
struct DelayPlan {
    inner: Arc<CompiledNet>,
    delay: Duration,
}

impl InferencePlan for DelayPlan {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn inputs(&self) -> &[TensorDef] {
        self.inner.inputs()
    }
    fn outputs(&self) -> &[String] {
        self.inner.outputs()
    }
    fn n_steps(&self) -> usize {
        self.inner.n_steps()
    }
    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        self.inner.check_inputs(inputs)
    }
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        std::thread::sleep(self.delay);
        self.inner.execute_positional(inputs)
    }
    fn batch_invariant(&self) -> bool {
        self.inner.batch_invariant()
    }
}

/// Poll `cond` until it holds or `timeout` elapses.
fn eventually(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

// ------------------------------------------------------- panic isolation

#[test]
fn worker_panic_fails_only_that_request_and_survivors_are_bit_identical() {
    let inner = scaled_plan(2.0);
    let plan = Arc::new(PanicPlan { inner: Arc::clone(&inner), sentinel: 1000.0 });
    let server = Server::start(
        plan,
        ServeConfig { workers: 1, max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 64 },
    );

    // the poisoned request gets a typed Internal, nothing else
    let bad = NdArray::from_slice(&[1, 2], &[2000.0, 0.0]);
    let err = server.infer(vec![bad]).unwrap_err();
    assert!(matches!(err, ServeError::Internal(_)), "{err}");
    assert!(err.to_string().contains("sentinel"), "{err}");
    assert!(!err.retryable(), "a panicking request is deterministic; never retry it");

    // the same worker keeps serving, and outputs stay bit-identical to
    // a direct solo execution of the underlying plan
    for i in 0..8 {
        let x = NdArray::from_slice(&[1, 2], &[i as f32, 1.0]);
        let got = server.infer(vec![x.clone()]).unwrap();
        let want = inner.execute_positional(std::slice::from_ref(&x)).unwrap();
        assert_eq!(got[0].dims(), want[0].dims());
        assert_eq!(got[0].data(), want[0].data(), "post-panic output diverged");
    }
    assert_eq!(server.alive_workers(), 1, "isolation must not cost the worker thread");

    let stats = server.shutdown();
    assert_eq!(stats.panics_caught, 1);
    assert_eq!(stats.worker_restarts, 0, "a caught panic needs no restart");
    assert_eq!(stats.requests, 9);
    assert_eq!(stats.errors, 1);
}

// ------------------------------------------------------------- deadlines

#[test]
fn deadline_expired_in_queue_is_shed_before_compute() {
    let inner = scaled_plan(1.0);
    let plan = Arc::new(DelayPlan { inner: Arc::clone(&inner), delay: Duration::from_millis(80) });
    let server = Server::start(
        plan,
        ServeConfig { workers: 1, max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 8 },
    );
    let x = NdArray::from_slice(&[1, 2], &[1.0, 0.0]);

    // occupy the only worker, then queue a request that cannot make it
    let blocker = server.submit(vec![x.clone()]).unwrap();
    let doomed = server
        .submit_with_deadline(vec![x.clone()], Duration::from_millis(5))
        .unwrap();
    let got = doomed.recv().unwrap().unwrap_err();
    match got {
        ServeError::DeadlineExceeded { waited_ms } => {
            assert!(waited_ms > 0, "shed request must report its queue wait");
        }
        other => panic!("expected DeadlineExceeded, got: {other}"),
    }
    blocker.recv().unwrap().unwrap();

    // a generous deadline gates queue wait, not compute: the 80 ms
    // execution still completes under a 5 s deadline
    let out = server
        .submit_with_deadline(vec![x.clone()], Duration::from_secs(5))
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(out[0].data(), inner.execute_positional(&[x]).unwrap()[0].data());

    let stats = server.shutdown();
    assert_eq!(stats.deadline_expired, 1);
}

#[test]
fn deadline_expired_mid_batch_sheds_only_the_expired_request() {
    // batch-invariant plan, one worker: a blocker pins the worker while
    // three requests queue behind it, one with a deadline that expires
    // during the wait — the batch must proceed with the survivors
    let inner = scaled_plan(1.0);
    let plan = Arc::new(DelayPlan { inner: Arc::clone(&inner), delay: Duration::from_millis(60) });
    let server = Server::start(
        plan,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 16,
        },
    );
    assert!(server.batched(), "this scenario needs micro-batching");

    let xs: Vec<NdArray> =
        (0..3).map(|i| NdArray::from_slice(&[1, 2], &[i as f32 + 1.0, 2.0])).collect();
    let blocker = server.submit(vec![NdArray::from_slice(&[1, 2], &[9.0, 9.0])]).unwrap();
    // wait out the blocker's own batch-fill window so the followers
    // queue behind an already-executing batch rather than joining it
    std::thread::sleep(Duration::from_millis(20));
    // queue order: survivor, doomed (5 ms deadline), survivor — the
    // doomed one is mid-queue so it is answered from the batch-fill
    // loop, not the head-of-queue pop
    let a = server.submit(vec![xs[0].clone()]).unwrap();
    let doomed = server
        .submit_with_deadline(vec![xs[1].clone()], Duration::from_millis(5))
        .unwrap();
    let c = server.submit(vec![xs[2].clone()]).unwrap();

    blocker.recv().unwrap().unwrap();
    let err = doomed.recv().unwrap().unwrap_err();
    assert!(matches!(err, ServeError::DeadlineExceeded { .. }), "{err}");
    for (rx, x) in [(a, &xs[0]), (c, &xs[2])] {
        let got = rx.recv().unwrap().expect("survivors must be served");
        let want = inner.execute_positional(std::slice::from_ref(x)).unwrap();
        assert_eq!(got[0].data(), want[0].data(), "survivor diverged");
    }
    let stats = server.shutdown();
    assert_eq!(stats.deadline_expired, 1);
    assert_eq!(stats.requests, 4, "every request is accounted, shed included");
}

#[test]
fn served_outputs_are_bit_identical_to_single_threaded_execution() {
    // the kernels are bit-deterministic across thread counts, so a
    // server on the default pool must reproduce an NNL_THREADS=1 run
    let (net, params) = nnl::models::zoo::export_eval("mlp", 17);
    let plan = Arc::new(CompiledNet::compile(&net, &params).unwrap());
    let mut rng = Rng::new(23);
    let inputs: Vec<NdArray> = (0..6).map(|_| rng.rand(&[1, 64], -1.0, 1.0)).collect();
    let reference: Vec<Vec<NdArray>> = inputs
        .iter()
        .map(|x| {
            parallel::with_thread_limit(1, || {
                plan.execute_positional(std::slice::from_ref(x)).unwrap()
            })
        })
        .collect();

    let server = Server::start(Arc::clone(&plan), ServeConfig::default());
    for (x, want) in inputs.iter().zip(&reference) {
        let got = server.infer(vec![x.clone()]).unwrap();
        assert_eq!(got[0].dims(), want[0].dims());
        assert_eq!(
            got[0].data(),
            want[0].data(),
            "served output diverged from the single-threaded reference"
        );
    }
    server.shutdown();
}

// ------------------------------------------------------------ frame caps

/// Read one `[u32 len][payload]` reply frame from a raw socket.
fn read_frame(stream: &mut std::net::TcpStream) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    Ok(payload)
}

#[test]
fn binary_frames_past_the_cap_get_a_typed_error_then_close() {
    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("m", scaled_plan(1.0), "f32");
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry), NetConfig::default())
        .expect("bind");
    let addr = server.local_addr();

    // property: EVERY claimed length past MAX_FRAME is refused with the
    // Protocol wire code before any payload is read, and the connection
    // closes (a desynchronized framing layer must not limp on)
    nnl::utils::prop::check(
        0xF8A3E,
        12,
        |rng| {
            let mut v = MAX_FRAME as u64 + 1 + rng.below(u32::MAX as usize - MAX_FRAME - 1) as u64;
            // a low byte of b'{' would switch the sniffer to JSON mode
            if v & 0xff == u64::from(b'{') {
                v += 1;
            }
            v
        },
        |&claimed| {
            let mut s = std::net::TcpStream::connect(addr).map_err(|e| e.to_string())?;
            s.write_all(&(claimed as u32).to_le_bytes()).map_err(|e| e.to_string())?;
            let payload = read_frame(&mut s).map_err(|e| e.to_string())?;
            if payload.get(1) != Some(&ServeError::Protocol(String::new()).code()) {
                return Err(format!("want wire code 6, got frame {payload:?}"));
            }
            // EOF follows: the server hung up after the typed reply
            let mut rest = Vec::new();
            s.read_to_end(&mut rest).map_err(|e| e.to_string())?;
            if !rest.is_empty() {
                return Err("connection stayed open past an unrecoverable framing error".into());
            }
            Ok(())
        },
    );
    // exactly at the cap the frame is admitted by framing (it then
    // fails decoding, typed, and the session continues)
    let mut cli = NetClient::connect(addr).unwrap();
    cli.ping().unwrap();
    server.shutdown();
}

#[test]
fn json_lines_past_the_cap_get_a_typed_error_then_close() {
    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("m", scaled_plan(1.0), "f32");
    let cfg = NetConfig { max_line: 2048, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry), cfg).expect("bind");
    let addr = server.local_addr();

    nnl::utils::prop::check(
        0xBEE5,
        6,
        |rng| 2049 + rng.below(8192),
        |&n| {
            let mut s = std::net::TcpStream::connect(addr).map_err(|e| e.to_string())?;
            // an endless JSON "line": opener plus n filler bytes, no \n
            s.write_all(b"{").map_err(|e| e.to_string())?;
            s.write_all(&vec![b' '; n]).map_err(|e| e.to_string())?;
            let mut reader = BufReader::new(s);
            let mut line = String::new();
            reader.read_line(&mut line).map_err(|e| e.to_string())?;
            if !(line.contains("\"ok\":false") && line.contains("protocol")) {
                return Err(format!("want a typed protocol error, got: {line}"));
            }
            if !line.contains("exceeds") {
                return Err(format!("error must name the cap violation: {line}"));
            }
            let mut rest = String::new();
            reader.read_line(&mut rest).map_err(|e| e.to_string())?;
            if !rest.is_empty() {
                return Err("connection stayed open past the line cap".into());
            }
            Ok(())
        },
    );
    // a line under the cap still round-trips on a fresh connection
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"verb\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.shutdown();
}

// ----------------------------------------------------- connection drops

#[test]
fn dropped_connections_release_gauges_and_never_wedge_the_server() {
    let registry = Arc::new(Registry::new(ServeConfig {
        workers: 2,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        queue_cap: 64,
    }));
    registry.deploy("m", scaled_plan(2.0), "f32");
    let cfg = NetConfig { max_conns: 4, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry), cfg).expect("bind");
    let addr = server.local_addr();

    let queue_depth = || {
        registry.stats_json().get("m").get("queue_depth").as_usize().unwrap_or(usize::MAX)
    };

    // round 1: sockets that die mid-frame (length prefix promises more
    // bytes than ever arrive)
    for _ in 0..6 {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&1000u32.to_le_bytes()).unwrap();
        s.write_all(&[PROTO_VERSION, 1, 0, 0, 0, 0]).unwrap();
        drop(s);
    }
    // round 2: full requests whose client hangs up without reading the
    // reply — the request still executes; the reply write fails; the
    // handler must clean up, not leak its slot or a queue entry
    for i in 0..6 {
        let mut payload = vec![PROTO_VERSION, 1u8]; // INFER "m", one [1,2] tensor
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.push(b'm');
        payload.push(1);
        payload.push(2);
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&2u32.to_le_bytes());
        payload.extend_from_slice(&(i as f32).to_le_bytes());
        payload.extend_from_slice(&0.0f32.to_le_bytes());
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
        s.write_all(&payload).unwrap();
        drop(s);
    }

    // the gauges settle back: no permanently-incremented queue depth,
    // and all connection slots come free again
    assert!(
        eventually(Duration::from_secs(5), || queue_depth() == 0),
        "queue_depth stuck at {} after connection drops",
        queue_depth()
    );

    // service is unharmed: fresh inference, a hot swap, and a full
    // complement of max_conns new connections all succeed
    let mut cli = NetClient::connect(addr).unwrap();
    let x = NdArray::from_slice(&[1, 2], &[3.0, 0.0]);
    assert_eq!(cli.infer("m", std::slice::from_ref(&x)).unwrap()[0].data()[0], 6.0);
    let v = registry.deploy("m", scaled_plan(4.0), "f32");
    assert_eq!(v, 2);
    assert_eq!(cli.infer("m", std::slice::from_ref(&x)).unwrap()[0].data()[0], 12.0);
    drop(cli);
    assert!(
        eventually(Duration::from_secs(5), || {
            let clients: Vec<_> =
                (0..4).filter_map(|_| NetClient::connect(addr).ok()).collect();
            clients.len() == 4
                && clients.into_iter().all(|mut c| c.ping().is_ok())
        }),
        "connection slots leaked: cannot open max_conns fresh connections"
    );
    server.shutdown();
}

// ------------------------------------------------------------- retries

#[test]
fn in_process_retry_recovers_overload_but_never_internal() {
    // a 1-deep queue and a slow plan force Overloaded; retry absorbs it
    let inner = scaled_plan(1.0);
    let plan = Arc::new(DelayPlan { inner, delay: Duration::from_millis(40) });
    let server = Server::start(
        plan,
        ServeConfig { workers: 1, max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 1 },
    );
    let client = server.client();
    let x = NdArray::from_slice(&[1, 2], &[5.0, 0.0]);
    let blocker = server.submit(vec![x.clone()]).unwrap();
    // let the worker pop the blocker so the filler owns the whole queue
    std::thread::sleep(Duration::from_millis(10));
    let filler = server.submit(vec![x.clone()]).unwrap();
    // queue is now full: a plain submit sheds, a retrying infer waits
    // out the blocker on its jittered backoff schedule
    assert!(matches!(
        server.submit(vec![x.clone()]).unwrap_err(),
        ServeError::Overloaded { .. }
    ));
    let policy = RetryPolicy {
        max_retries: 50,
        base: Duration::from_millis(10),
        cap: Duration::from_millis(40),
        seed: 11,
    };
    let out = client.infer_with_retry(vec![x.clone()], &policy).expect("retry must recover");
    assert_eq!(out[0].data()[0], 5.0);
    blocker.recv().unwrap().unwrap();
    filler.recv().unwrap().unwrap();
    let stats = server.shutdown();
    assert!(stats.retries > 0, "the recovery above must have counted retries");

    // Internal is never retried: a poisoned request fails once, fast
    let plan = Arc::new(PanicPlan { inner: scaled_plan(1.0), sentinel: 100.0 });
    let server = Server::start(
        plan,
        ServeConfig { workers: 1, max_batch: 1, max_wait: Duration::from_millis(1), queue_cap: 8 },
    );
    let bad = NdArray::from_slice(&[1, 2], &[500.0, 0.0]);
    let err = server
        .client()
        .infer_with_retry(vec![bad], &RetryPolicy::default())
        .unwrap_err();
    assert!(matches!(err, ServeError::Internal(_)), "{err}");
    let stats = server.shutdown();
    assert_eq!(stats.retries, 0, "Internal must not burn retry budget");
    assert_eq!(stats.panics_caught, 1);
}

#[test]
fn retry_backoff_is_deterministic_jittered_and_capped() {
    let p = RetryPolicy {
        max_retries: 5,
        base: Duration::from_millis(4),
        cap: Duration::from_millis(20),
        seed: 99,
    };
    for attempt in 0..6 {
        let d = p.backoff(attempt, 1);
        assert_eq!(d, p.backoff(attempt, 1), "same seed/salt must replay identically");
        assert!(d <= Duration::from_millis(20), "cap violated at attempt {attempt}: {d:?}");
        assert!(d >= Duration::from_micros(50), "degenerate backoff at attempt {attempt}");
    }
    assert_ne!(p.backoff(2, 1), p.backoff(2, 2), "salt must decorrelate clients");
}

// --------------------------------------------------------------- health

#[test]
fn health_verb_reports_readiness_over_the_wire() {
    let registry = Arc::new(Registry::new(ServeConfig::default()));
    registry.deploy("m", scaled_plan(1.0), "f32");
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&registry), NetConfig::default())
        .expect("bind");
    let addr = server.local_addr();

    // binary protocol
    let mut cli = NetClient::connect(addr).unwrap();
    let h = cli.health().unwrap();
    assert_eq!(h.get("ready").as_bool(), Some(true));
    assert_eq!(h.get("models").get("m").get("ready").as_bool(), Some(true));
    assert!(h.get("models").get("m").get("workers_alive").as_usize().unwrap() > 0);
    assert_eq!(h.get("models").get("m").get("worker_restarts").as_usize(), Some(0));

    // JSON fallback on a raw socket
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"verb\":\"health\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    assert!(line.contains("\"ready\":true"), "{line}");

    // an emptied registry is not ready — there is nothing to serve
    registry.remove("m");
    let h = cli.health().unwrap();
    assert_eq!(h.get("ready").as_bool(), Some(false));
    server.shutdown();
}
