//! Loom models of the crate's three hand-rolled concurrency protocols.
//!
//! `loom` is deliberately **not** a dependency of this crate (the build
//! must work offline); the whole file is gated behind `--cfg loom`, so a
//! normal `cargo test` compiles it to nothing. CI's loom job does:
//!
//! ```sh
//! cargo add --dev loom          # on the runner only
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Each model re-implements the protocol under test with loom's
//! permutation-exploring primitives, at a scale small enough to
//! exhaustively check every interleaving. The models mirror, line for
//! line where it matters, the real implementations:
//!
//! - the bounded Condvar queue in `serve::Queue` (push / pop / close):
//!   no admitted request is ever lost, and `pop` returns `None` only
//!   once the queue is closed *and* drained;
//! - the `ModelSlot` hot swap in `serve::net` (`RwLock<Arc<Hosted>>`):
//!   versions observed by readers are monotone, a reader that pinned an
//!   incarnation can use it across a concurrent swap, and the retired
//!   incarnation is dropped exactly once, outside the lock;
//! - the worker-pool claim/done drain in `tensor::parallel`: every
//!   chunk executes exactly once and the submitter's completion wait
//!   cannot return before all chunks finished.
//!
//! Keeping the models in-tree next to an honest comment trail is the
//! point: when one of the real implementations changes shape, the model
//! that no longer matches is the review flag.

#![cfg(loom)]

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;
use std::collections::VecDeque;

// ---------------------------------------------------------------------
// Model 1: the bounded serve queue (serve::Queue)
// ---------------------------------------------------------------------

struct QueueState {
    items: VecDeque<u32>,
    closed: bool,
}

/// Condvar-guarded bounded deque, shaped exactly like `serve::Queue`:
/// `push` rejects when full or closed, `pop` parks on the condvar and
/// returns `None` only once closed-and-drained, `close` marks closed
/// and wakes every parked worker so the backlog drains to completion.
struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap,
        }
    }

    /// `Ok(())` if admitted; `Err(())` if closed or full (the real queue
    /// distinguishes ShuttingDown from Overloaded — irrelevant here).
    fn push(&self, v: u32) -> Result<(), ()> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.cap {
            return Err(());
        }
        st.items.push_back(v);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self) -> Option<u32> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Two producers race a close against a draining worker: every item the
/// producers saw admitted must come out of `pop` exactly once, and the
/// worker's final `pop` must be `None` (closed and drained), never a
/// hang or a lost request. This is the graceful-shutdown invariant the
/// serve front end documents.
#[test]
fn loom_queue_never_loses_admitted_items() {
    loom::model(|| {
        let q = Arc::new(Queue::new(2));

        let producers: Vec<_> = (0..2u32)
            .map(|id| {
                let q = Arc::clone(&q);
                thread::spawn(move || q.push(id).is_ok())
            })
            .collect();

        let closer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.close())
        };

        // The "worker": drain until closed-and-drained.
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }

        let admitted: usize =
            producers.into_iter().map(|h| h.join().unwrap() as usize).sum();
        closer.join().unwrap();

        // Everything admitted before the close is delivered exactly once.
        assert_eq!(got.len(), admitted, "admitted {admitted}, delivered {got:?}");
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), admitted, "duplicate delivery: {got:?}");
    });
}

/// A full queue must reject (bounded backpressure), never block the
/// submitter or overwrite a queued request.
#[test]
fn loom_queue_bounds_are_hard() {
    loom::model(|| {
        let q = Arc::new(Queue::new(1));
        let t = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(1).is_ok())
        };
        let mine = q.push(2).is_ok();
        let theirs = t.join().unwrap();
        // cap 1: exactly one of the two racing pushes is admitted
        assert!(mine ^ theirs, "cap-1 queue admitted {}", mine as u32 + theirs as u32);
        q.close();
        assert_eq!(q.pop().map(|_| ()), Some(()));
        assert_eq!(q.pop(), None);
    });
}

// ---------------------------------------------------------------------
// Model 2: ModelSlot hot swap (serve::net)
// ---------------------------------------------------------------------

/// Stand-in for `Hosted`: the drop counter lets the model assert the
/// retired incarnation is dropped exactly once, and only after every
/// pinned reader let go.
struct Hosted {
    version: u64,
    drops: Arc<AtomicUsize>,
}

impl Drop for Hosted {
    fn drop(&mut self) {
        self.drops.fetch_add(1, Ordering::Relaxed);
    }
}

use loom::sync::RwLock;

/// `deploy`'s swap protocol: read the old version under the read lock,
/// build the replacement, `mem::replace` under the write lock, and drop
/// the retired `Arc` *outside* the lock (its real Drop joins a worker
/// pool and must never stall submitters).
fn swap(slot: &RwLock<Arc<Hosted>>, drops: &Arc<AtomicUsize>) -> u64 {
    let version = slot.read().unwrap().version + 1;
    let next = Arc::new(Hosted { version, drops: Arc::clone(drops) });
    let retired = std::mem::replace(&mut *slot.write().unwrap(), next);
    drop(retired); // outside the write lock
    version
}

/// A reader pins an incarnation (clones the `Arc` under the read lock,
/// as `Registry::submit` does) while a swap runs. The pinned
/// incarnation must stay usable across the swap, observed versions must
/// be monotone, and the old incarnation must be dropped exactly once —
/// only after the pin is released.
#[test]
fn loom_hot_swap_keeps_pinned_incarnation_alive() {
    loom::model(|| {
        let drops = Arc::new(AtomicUsize::new(0));
        let slot = Arc::new(RwLock::new(Arc::new(Hosted {
            version: 1,
            drops: Arc::clone(&drops),
        })));

        let swapper = {
            let slot = Arc::clone(&slot);
            let drops = Arc::clone(&drops);
            thread::spawn(move || swap(&slot, &drops))
        };

        // Reader: pin, observe, use across whatever the swapper does.
        let pinned = Arc::clone(&*slot.read().unwrap());
        let v1 = pinned.version;
        let v2 = slot.read().unwrap().version;
        assert!(v2 >= v1, "reader saw version go backwards: {v1} -> {v2}");
        // the pin is still alive regardless of the swap
        assert!(pinned.version >= 1);
        drop(pinned);

        let new_version = swapper.join().unwrap();
        assert_eq!(new_version, 2);
        assert_eq!(slot.read().unwrap().version, 2);
        // exactly the one retired incarnation dropped, no double free
        assert_eq!(drops.load(Ordering::Relaxed), 1);
    });
}

/// Two concurrent swaps: versions still end monotone and both retired
/// incarnations drop exactly once. (The real registry serialises the
/// version read and the replace under the same outer map lock; the slot
/// lock alone already guarantees no incarnation is leaked or
/// double-dropped, which is what this model checks.)
#[test]
fn loom_concurrent_swaps_retire_exactly_once() {
    loom::model(|| {
        let drops = Arc::new(AtomicUsize::new(0));
        let slot = Arc::new(RwLock::new(Arc::new(Hosted {
            version: 1,
            drops: Arc::clone(&drops),
        })));
        let t = {
            let slot = Arc::clone(&slot);
            let drops = Arc::clone(&drops);
            thread::spawn(move || swap(&slot, &drops))
        };
        swap(&slot, &drops);
        t.join().unwrap();
        let final_version = slot.read().unwrap().version;
        assert!(final_version >= 2, "two swaps left version {final_version}");
        drop(slot);
        // both swapped-out incarnations plus the final one are gone
        assert_eq!(drops.load(Ordering::Relaxed), 3);
    });
}

// ---------------------------------------------------------------------
// Model 3: worker-pool claim/done drain (tensor::parallel)
// ---------------------------------------------------------------------

/// The `Job` counters, as in `tensor::parallel::Job`: `claimed` may
/// overshoot `n_chunks`; `done` counts completed chunks with `Release`
/// so the submitter's `Acquire` wait synchronises with the last chunk's
/// writes.
struct Job {
    n_chunks: usize,
    claimed: AtomicUsize,
    done: AtomicUsize,
    /// Stands in for the output buffer behind `RunPtr`: one slot per
    /// chunk, each incremented by whoever executes that chunk.
    executed: Vec<AtomicUsize>,
}

/// `tensor::parallel::drain`, verbatim modulo the closure call.
fn drain(job: &Job) {
    loop {
        let i = job.claimed.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_chunks {
            return;
        }
        job.executed[i].fetch_add(1, Ordering::Relaxed);
        job.done.fetch_add(1, Ordering::Release);
    }
}

/// Submitter + one worker both drain the same job; the submitter then
/// spins on `done` with `Acquire` (the real code parks on a condvar —
/// the memory-ordering claim under test is identical). Every chunk must
/// execute exactly once, and the completion wait must not pass early.
#[test]
fn loom_pool_drain_runs_every_chunk_exactly_once() {
    loom::model(|| {
        const N: usize = 3;
        let job = Arc::new(Job {
            n_chunks: N,
            claimed: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            executed: (0..N).map(|_| AtomicUsize::new(0)).collect(),
        });

        let worker = {
            let job = Arc::clone(&job);
            thread::spawn(move || drain(&job))
        };

        drain(&job);
        // submitter's completion wait (loom has no condvar timeout
        // pressure here; yielding keeps the schedule space bounded)
        while job.done.load(Ordering::Acquire) < N {
            loom::thread::yield_now();
        }

        // `done == n_chunks` with Acquire/Release pairing means every
        // chunk's effect is visible now — before the worker even joins.
        for (i, slot) in job.executed.iter().enumerate() {
            assert_eq!(slot.load(Ordering::Relaxed), 1, "chunk {i} ran != once");
        }
        worker.join().unwrap();
    });
}

/// Late joiner: a worker that arrives after all chunks were claimed
/// must fall straight through `drain` without touching anything —
/// this is what makes it safe for the submitter to free the closure
/// once `done == n_chunks` (the `RunPtr` dereference-after-claim rule
/// documented in `tensor::parallel::drain`).
#[test]
fn loom_pool_late_joiner_claims_nothing() {
    loom::model(|| {
        const N: usize = 2;
        let job = Arc::new(Job {
            n_chunks: N,
            claimed: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            executed: (0..N).map(|_| AtomicUsize::new(0)).collect(),
        });
        let late = {
            let job = Arc::clone(&job);
            thread::spawn(move || drain(&job))
        };
        drain(&job);
        late.join().unwrap();
        while job.done.load(Ordering::Acquire) < N {
            loom::thread::yield_now();
        }
        let total: usize =
            job.executed.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        assert_eq!(total, N, "chunks executed {total} times, want {N}");
        // claimed overshoots by exactly the number of empty claims; it
        // never exceeds n_chunks + participants
        assert!(job.claimed.load(Ordering::Relaxed) <= N + 2);
    });
}
