//! Int8 quantization parity and robustness suite (CI re-runs it under
//! `NNL_THREADS=1` and under both `NNL_ISA=scalar` / `NNL_ISA=auto`):
//! zoo-model fp32-vs-int8 agreement, thread-count bit-identity of the
//! quantized path, SIMD-tier bit-identity (the int8 kernels promise
//! the exact scalar bits at every ISA), NNB2 size/roundtrip
//! guarantees, and decoder property tests over truncations and byte
//! flips.

use std::collections::HashMap;

use nnl::bench_quant;
use nnl::converters::nnb;
use nnl::models::zoo;
use nnl::nnp::{CompiledNet, InferencePlan, NetworkDef};
use nnl::quant::{quantize_net, referenced_params, QuantConfig, QuantizedNet};
use nnl::tensor::kernels::dispatch;
use nnl::tensor::{parallel, NdArray, Rng};
use nnl::utils::prop;

/// Batch-1 random positional inputs for `net`, from a fresh seed.
fn random_inputs(net: &NetworkDef, n: usize, seed: u64) -> Vec<Vec<NdArray>> {
    bench_quant::random_inputs(net, n, &mut Rng::new(seed))
}

/// Quantize a zoo model on 16 calibration samples.
fn quantized_zoo(name: &str) -> (NetworkDef, HashMap<String, NdArray>, QuantizedNet) {
    let (net, params) = zoo::export_eval(name, 11);
    let calib = random_inputs(&net, 16, 77);
    let (_, qnet) =
        quantize_net(&net, &params, &calib, &QuantConfig::default()).expect("quantizes");
    (net, params, qnet)
}

#[test]
fn quantized_mlp_top1_agrees_with_fp32() {
    let (net, params, qnet) = quantized_zoo("mlp");
    // all three affine layers take the int8 path
    assert_eq!(qnet.n_quantized(), 3, "quantized: {:?}", qnet.quantized_layers());
    let plan = CompiledNet::compile(&net, &params).unwrap();
    let evals = random_inputs(&net, 64, 78);
    let agree = evals
        .iter()
        .filter(|s| {
            let f = plan.execute_positional(s.as_slice()).unwrap();
            let q = qnet.execute_positional(s.as_slice()).unwrap();
            assert!(!q[0].has_inf_or_nan(), "int8 produced inf/nan");
            f[0].argmax_flat() == q[0].argmax_flat()
        })
        .count();
    assert!(agree * 100 >= evals.len() * 95, "top-1 agreement {agree}/{}", evals.len());
}

#[test]
fn quantized_lenet_conv_path_agrees_with_fp32() {
    let (net, params, qnet) = quantized_zoo("lenet");
    // 2 convolutions + 2 affines ride the int8 GEMM
    assert_eq!(qnet.n_quantized(), 4, "quantized: {:?}", qnet.quantized_layers());
    let plan = CompiledNet::compile(&net, &params).unwrap();
    let evals = random_inputs(&net, 32, 79);
    let agree = evals
        .iter()
        .filter(|s| {
            let f = plan.execute_positional(s.as_slice()).unwrap();
            let q = qnet.execute_positional(s.as_slice()).unwrap();
            f[0].argmax_flat() == q[0].argmax_flat()
        })
        .count();
    assert!(agree * 100 >= evals.len() * 90, "top-1 agreement {agree}/{}", evals.len());
}

#[test]
fn quantized_path_is_bit_identical_at_any_thread_count() {
    let (net, _, qnet) = quantized_zoo("lenet");
    for s in random_inputs(&net, 4, 80) {
        let full = qnet.execute_positional(&s).unwrap();
        let serial =
            parallel::with_thread_limit(1, || qnet.execute_positional(&s).unwrap());
        for (a, b) in full.iter().zip(&serial) {
            assert_eq!(a.dims(), b.dims());
            assert_eq!(a.data(), b.data(), "thread count changed quantized output bits");
        }
    }
}

/// The int8 path's SIMD contract is *exact*: the vectorized u8×i8
/// kernels accumulate the same i32 sums (integer addition commutes)
/// and requantize with the same mul-then-add rounding as the scalar
/// loop, so every ISA tier must reproduce the scalar bits across the
/// whole zoo — at the default pool width and at one thread.
#[test]
fn quantized_zoo_is_bit_identical_to_scalar_at_every_isa() {
    for name in ["mlp", "lenet"] {
        let (net, _, qnet) = quantized_zoo(name);
        for s in random_inputs(&net, 3, 89) {
            let scalar =
                dispatch::with_isa(dispatch::Isa::Scalar, || qnet.execute_positional(&s).unwrap());
            for isa in dispatch::available_isas() {
                let full = dispatch::with_isa(isa, || qnet.execute_positional(&s).unwrap());
                let serial = dispatch::with_isa(isa, || {
                    parallel::with_thread_limit(1, || qnet.execute_positional(&s).unwrap())
                });
                for (got, want) in full.iter().chain(serial.iter()).zip(scalar.iter().cycle()) {
                    assert_eq!(got.dims(), want.dims());
                    assert_eq!(
                        got.data(),
                        want.data(),
                        "{name} [{}]: int8 output bits differ from scalar",
                        isa.name()
                    );
                }
            }
        }
    }
}

#[test]
fn nnb2_zoo_artifacts_are_3x_smaller_and_roundtrip() {
    for name in ["mlp", "lenet"] {
        let (net, params) = zoo::export_eval(name, 11);
        let calib = random_inputs(&net, 8, 81);
        let (model, qnet) =
            quantize_net(&net, &params, &calib, &QuantConfig::default()).unwrap();
        // v1 counterpart carries the same referenced params as f32
        let v1 = nnb::to_nnb(&net, &referenced_params(&net, &params));
        let v2 = nnb::to_nnb2(&model);
        assert!(
            v2.len() * 3 <= v1.len(),
            "{name}: NNB2 {} B vs NNB1 {} B is under 3x",
            v2.len(),
            v1.len()
        );
        // decode + compile + execute == the in-memory quantized net
        let engine = nnb::NnbEngine::load(&v2).unwrap();
        let x = random_inputs(&net, 1, 82).pop().unwrap();
        let from_disk = match &engine {
            nnb::NnbEngine::Int8(q) => q.execute_positional(&x).unwrap(),
            nnb::NnbEngine::F32(_) => panic!("NNB2 must load as a quantized plan"),
        };
        let in_memory = qnet.execute_positional(&x).unwrap();
        assert_eq!(from_disk[0].data(), in_memory[0].data(), "{name} roundtrip drifted");
    }
}

#[test]
fn nnb_decoder_never_panics_on_truncation() {
    let (net, params) = zoo::export_eval("mlp", 11);
    let calib = random_inputs(&net, 4, 83);
    let (model, _) = quantize_net(&net, &params, &calib, &QuantConfig::default()).unwrap();
    let v1 = nnb::to_nnb(&net, &referenced_params(&net, &params));
    let v2 = nnb::to_nnb2(&model);
    // every strict prefix must decode to Err — never a panic
    prop::check(
        84,
        200,
        |rng| rng.below(v1.len()),
        |&cut| match nnb::from_nnb(&v1[..cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("v1 prefix of {cut} bytes decoded")),
        },
    );
    prop::check(
        85,
        200,
        |rng| rng.below(v2.len()),
        |&cut| match nnb::from_nnb2(&v2[..cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("v2 prefix of {cut} bytes decoded")),
        },
    );
}

#[test]
fn nnb_decoder_never_panics_on_byte_flips() {
    let (net, params) = zoo::export_eval("mlp", 11);
    let calib = random_inputs(&net, 4, 86);
    let (model, _) = quantize_net(&net, &params, &calib, &QuantConfig::default()).unwrap();
    let v2 = nnb::to_nnb2(&model);
    let v1 = nnb::to_nnb(&net, &referenced_params(&net, &params));
    // a flip may still decode (e.g. inside weight data) — the property
    // is that decoding terminates with Ok or Err, never a panic/abort
    prop::check(
        87,
        300,
        |rng| (rng.below(v1.len()), 1u8 << rng.below(8)),
        |&(pos, mask)| {
            let mut bytes = v1.clone();
            bytes[pos] ^= mask;
            let _ = nnb::load_nnb(&bytes);
            Ok(())
        },
    );
    prop::check(
        88,
        300,
        |rng| (rng.below(v2.len()), 1u8 << rng.below(8)),
        |&(pos, mask)| {
            let mut bytes = v2.clone();
            bytes[pos] ^= mask;
            let _ = nnb::load_nnb(&bytes);
            Ok(())
        },
    );
}

#[test]
fn quantized_plan_rejects_bad_shapes_cleanly() {
    let (_, _, qnet) = quantized_zoo("mlp");
    // wrong rank
    let err = qnet.execute_positional(&[NdArray::zeros(&[64])]).unwrap_err();
    assert!(err.contains("incompatible"), "{err}");
    // wrong feature count
    let err = qnet.execute_positional(&[NdArray::zeros(&[1, 63])]).unwrap_err();
    assert!(err.contains("incompatible"), "{err}");
}
