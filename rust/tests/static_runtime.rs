//! Integration: load real AOT artifacts (built by `make artifacts`)
//! and execute them through PJRT — the full L1/L2 → L3 bridge.

use nnl::runtime::{Manifest, StaticExecutable};
use nnl::tensor::{ops, NdArray, Rng};

/// Loads the manifest and compiles `name`. With the `pjrt` feature on
/// (the configuration these tests exist for) a missing manifest or a
/// failed load is a hard failure — no silent green. Without it the
/// tests are `#[ignore]`d anyway; the `None` path only soft-skips when
/// someone forces ignored tests in a stub build.
fn load_exe(name: &str) -> Option<StaticExecutable> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        assert!(
            !cfg!(feature = "pjrt"),
            "artifacts missing — run `make artifacts` first (looked in {})",
            dir.display()
        );
        eprintln!("skipping: artifacts missing — run `make artifacts` (looked in {})", dir.display());
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    match StaticExecutable::load(&m, name) {
        Ok(exe) => Some(exe),
        Err(e) => {
            assert!(!cfg!(feature = "pjrt"), "static runtime failed to load '{name}': {e}");
            eprintln!("skipping: static runtime unavailable: {e}");
            None
        }
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn matmul_artifact_matches_rust_matmul() {
    let Some(exe) = load_exe("matmul_f32_256") else { return };
    let mut rng = Rng::new(1);
    let a = rng.randn(&[256, 256], 1.0);
    let b = rng.randn(&[256, 256], 1.0);
    let out = exe.execute(&[a.clone(), b.clone()]).unwrap();
    let expect = ops::matmul(&a, &b);
    assert!(
        out[0].allclose(&expect, 1e-2, 1e-3),
        "pallas-kernel artifact disagrees with rust matmul: max diff {}",
        out[0].max_abs_diff(&expect)
    );
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn matmul_bf16_artifact_quantizes_inputs() {
    let Some(exe) = load_exe("matmul_bf16_256") else { return };
    let mut rng = Rng::new(2);
    let a = rng.randn(&[256, 256], 1.0);
    let b = rng.randn(&[256, 256], 1.0);
    let out = exe.execute(&[a.clone(), b.clone()]).unwrap();
    // reference with bf16-quantized inputs, f32 accumulation
    let aq = a.cast(nnl::tensor::DType::BF16);
    let bq = b.cast(nnl::tensor::DType::BF16);
    let expect = ops::matmul(&aq, &bq);
    assert!(
        out[0].allclose(&expect, 0.3, 2e-2),
        "bf16 artifact out of tolerance: max diff {}",
        out[0].max_abs_diff(&expect)
    );
    // and it must differ from the full-precision product (proving the
    // cast actually happened)
    let full = ops::matmul(&a, &b);
    assert!(out[0].max_abs_diff(&full) > 1e-4);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn mlp_train_step_returns_grads_and_loss() {
    let Some(exe) = load_exe("mlp_train_f32_b32") else { return };
    let spec = exe.spec().clone();
    let params = spec.init_params();
    let mut rng = Rng::new(3);
    let x = rng.randn(&[32, 64], 1.0);
    let mut y = NdArray::zeros(&[32]);
    for i in 0..32 {
        y.data_mut()[i] = (i % 10) as f32;
    }
    let mut inputs: Vec<NdArray> = params.iter().map(|(_, a)| a.clone()).collect();
    inputs.push(x);
    inputs.push(y);
    inputs.push(NdArray::scalar(1.0));
    let out = exe.execute(&inputs).unwrap();
    assert_eq!(out.len(), params.len() + 1);
    let loss = out.last().unwrap().item();
    // fresh init, 10 classes: loss ~ ln(10)
    assert!((loss - 10f32.ln()).abs() < 0.7, "initial loss {loss}");
    // grads flow: at least one grad nonzero per layer pair
    for (g, (name, _)) in out[..params.len()].iter().zip(&params) {
        assert!(!g.has_inf_or_nan(), "grad {name} has inf/nan");
    }
    assert!(out[0].norm2() > 0.0);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn mlp_loss_scaling_scales_grads_linearly() {
    let Some(exe) = load_exe("mlp_train_f32_b32") else { return };
    let params = exe.spec().init_params();
    let mut rng = Rng::new(4);
    let x = rng.randn(&[32, 64], 1.0);
    let y = NdArray::zeros(&[32]);
    let mut base: Vec<NdArray> = params.iter().map(|(_, a)| a.clone()).collect();
    base.push(x);
    base.push(y);
    let mut in1 = base.clone();
    in1.push(NdArray::scalar(1.0));
    let mut in8 = base.clone();
    in8.push(NdArray::scalar(8.0));
    let o1 = exe.execute(&in1).unwrap();
    let o8 = exe.execute(&in8).unwrap();
    // grads scale by 8, loss unchanged (Listing 6 contract)
    let g1 = &o1[0];
    let g8 = &o8[0];
    assert!(ops::scale(g1, 8.0).allclose(g8, 1e-4, 1e-3));
    assert!((o1.last().unwrap().item() - o8.last().unwrap().item()).abs() < 1e-4);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn static_mlp_training_reduces_loss() {
    // mini end-to-end: 30 SGD steps on a separable synthetic problem
    let Some(exe) = load_exe("mlp_train_f32_b32") else { return };
    let mut params: Vec<NdArray> =
        exe.spec().init_params().into_iter().map(|(_, a)| a).collect();
    let mut rng = Rng::new(5);
    // class-dependent mean shift: learnable
    let mut x = rng.randn(&[32, 64], 1.0);
    let mut y = NdArray::zeros(&[32]);
    for i in 0..32 {
        let c = i % 10;
        y.data_mut()[i] = c as f32;
        for j in 0..64 {
            x.data_mut()[i * 64 + j] += if j % 10 == c { 2.0 } else { 0.0 };
        }
    }
    let mut first = 0.0;
    let mut last = 0.0;
    for it in 0..30 {
        let mut inputs = params.clone();
        inputs.push(x.clone());
        inputs.push(y.clone());
        inputs.push(NdArray::scalar(1.0));
        let out = exe.execute(&inputs).unwrap();
        let loss = out.last().unwrap().item();
        if it == 0 {
            first = loss;
        }
        last = loss;
        for (p, g) in params.iter_mut().zip(&out[..]) {
            *p = ops::sub(p, &ops::scale(g, 0.1));
        }
    }
    assert!(
        last < first * 0.5,
        "static training did not learn: {first} -> {last}"
    );
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn infer_artifact_shapes() {
    let Some(exe) = load_exe("mlp_infer_f32_b32") else { return };
    let params = exe.spec().init_params();
    let mut rng = Rng::new(6);
    let mut inputs: Vec<NdArray> = params.into_iter().map(|(_, a)| a).collect();
    inputs.push(rng.randn(&[32, 64], 1.0));
    let out = exe.execute(&inputs).unwrap();
    assert_eq!(out[0].dims(), &[32, 10]);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn wrong_input_shape_rejected() {
    let Some(exe) = load_exe("matmul_f32_256") else { return };
    let a = NdArray::zeros(&[128, 256]);
    let b = NdArray::zeros(&[256, 256]);
    let err = exe.execute(&[a, b]).unwrap_err();
    assert!(err.to_string().contains("shape"));
}
