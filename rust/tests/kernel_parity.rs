//! Kernel parity + determinism suite for the tiled, multi-threaded
//! compute floor:
//!
//! - property tests pinning the packed tiled GEMM, the fused
//!   im2col-GEMM convolution, and col2im against the pre-PR naive
//!   implementations over randomized shapes and geometries;
//! - bit-identity tests for the pool's determinism contract — every
//!   parallel kernel must produce the same bits at 1, 2 and N threads
//!   (CI also runs this whole suite under `NNL_THREADS=1`);
//! - plan-vs-tape bit-identity for the fused Affine/Convolution fast
//!   paths in `CompiledNet::execute`;
//! - SIMD-tier coverage for the dispatched microkernels: degenerate
//!   shapes (k=0, m/n=1, off-grid tails) at every executable ISA,
//!   scalar-vs-dispatched agreement within the ≤ 1e-5 relative
//!   contract, per-ISA thread-count bit-identity, and `NNL_ISA`
//!   pinning (CI runs this suite under both `NNL_ISA=scalar` and
//!   `NNL_ISA=auto`).

use std::collections::HashMap;

use nnl::functions as F;
use nnl::nnp::{CompiledNet, Layer, NetworkDef, Op, TensorDef};
use nnl::tensor::kernels::dispatch::{self, Isa};
use nnl::tensor::ops::{self, Conv2dGeom};
use nnl::tensor::{parallel, NdArray, Rng};
use nnl::utils::prop;
use nnl::Variable;

// ------------------------------------------------------------- GEMM parity

#[test]
fn gemm_matches_naive_over_random_shapes() {
    prop::check(
        101,
        24,
        |rng| {
            // straddle the small/tiled cutoff and every edge-tile case
            let m = 1 + rng.below(96);
            let k = 1 + rng.below(96);
            let n = 1 + rng.below(96);
            let a = rng.randn(&[m, k], 1.0);
            let b = rng.randn(&[k, n], 1.0);
            (a, b)
        },
        |(a, b)| {
            let got = ops::matmul(a, b);
            let want = ops::matmul_naive(a, b);
            if got.allclose(&want, 1e-4, 1e-4) {
                Ok(())
            } else {
                Err(format!(
                    "{}x{} · {}x{}: max diff {}",
                    a.dims()[0],
                    a.dims()[1],
                    b.dims()[0],
                    b.dims()[1],
                    got.max_abs_diff(&want)
                ))
            }
        },
    );
}

#[test]
fn batch_matmul_matches_per_slice_matmul_bitwise() {
    let mut rng = Rng::new(102);
    let a = rng.randn(&[3, 33, 21], 1.0);
    let b = rng.randn(&[3, 21, 17], 1.0);
    let c = ops::batch_matmul(&a, &b);
    for i in 0..3 {
        let ai = a.slice_axis(0, i, i + 1).reshape(&[33, 21]);
        let bi = b.slice_axis(0, i, i + 1).reshape(&[21, 17]);
        let want = ops::matmul(&ai, &bi);
        let got = c.slice_axis(0, i, i + 1).reshape(&[33, 17]);
        assert_eq!(got.data(), want.data(), "batch {i} differs");
    }
}

// --------------------------------------------------------------- conv parity

fn rand_geom(rng: &mut Rng) -> Conv2dGeom {
    Conv2dGeom {
        kernel: (1 + rng.below(3), 1 + rng.below(3)),
        stride: (1 + rng.below(2), 1 + rng.below(2)),
        pad: (rng.below(2), rng.below(2)),
        dilation: (1 + rng.below(2), 1 + rng.below(2)),
    }
}

#[test]
fn fused_conv_forward_matches_materialized_lowering() {
    prop::check(
        103,
        16,
        |rng| {
            let n = 1 + rng.below(2);
            let c = 1 + rng.below(4);
            let oc = 1 + rng.below(6);
            let h = 6 + rng.below(8);
            let w = 6 + rng.below(8);
            let g = rand_geom(rng);
            let x = rng.randn(&[n, c, h, w], 1.0);
            let wt = rng.randn(&[oc, c, g.kernel.0, g.kernel.1], 1.0);
            let b = rng.randn(&[oc], 1.0);
            (x, wt, b, g)
        },
        |(x, wt, b, g)| {
            let (h, w) = (x.dims()[2], x.dims()[3]);
            let Some((oh, ow)) = g.try_out_hw(h, w) else {
                return Ok(()); // degenerate geometry drawn: skip
            };
            let (n, oc) = (x.dims()[0], wt.dims()[0]);
            let xv = Variable::from_array(x.clone(), false);
            let wv = Variable::from_array(wt.clone(), false);
            let bv = Variable::from_array(b.clone(), false);
            let y = F::convolution(&xv, &wv, Some(&bv), g.stride, g.pad, g.dilation).data();
            // pre-PR reference: materialized im2col + naive matmul
            let cols = ops::im2col(x, g);
            let wr = wt.reshape(&[oc, wt.size() / oc]).t();
            let yr = ops::add(&ops::matmul_naive(&cols, &wr), b);
            let want = yr.reshape(&[n, oh, ow, oc]).transpose(&[0, 3, 1, 2]);
            if y.allclose(&want, 1e-4, 1e-4) {
                Ok(())
            } else {
                Err(format!(
                    "x {:?} w {:?} geom {g:?}: max diff {}",
                    x.dims(),
                    wt.dims(),
                    y.max_abs_diff(&want)
                ))
            }
        },
    );
}

#[test]
fn fused_conv_backward_matches_materialized_lowering() {
    prop::check(
        104,
        10,
        |rng| {
            let c = 1 + rng.below(3);
            let oc = 1 + rng.below(4);
            let g = rand_geom(rng);
            let x = rng.randn(&[2, c, 9, 9], 1.0);
            let wt = rng.randn(&[oc, c, g.kernel.0, g.kernel.1], 1.0);
            (x, wt, g)
        },
        |(x, wt, g)| {
            let Some((oh, ow)) = g.try_out_hw(9, 9) else {
                return Ok(());
            };
            let (n, oc) = (2, wt.dims()[0]);
            let xv = Variable::from_array(x.clone(), true);
            let wv = Variable::from_array(wt.clone(), true);
            let y = F::convolution(&xv, &wv, None, g.stride, g.pad, g.dilation);
            // seed backward with ones (sum objective): grads via tape
            F::sum_all(&y).backward();
            let (gx, gw) = (xv.grad(), wv.grad());
            // reference gradients from the materialized formulas
            let gyr = NdArray::ones(&[n * oh * ow, oc]);
            let wr = wt.reshape(&[oc, wt.size() / oc]);
            let want_gx = ops::col2im(&ops::matmul_naive(&gyr, &wr), x.dims(), g);
            let want_gw =
                ops::matmul_naive(&gyr.t(), &ops::im2col(x, g)).reshape(wt.dims());
            if gx.allclose(&want_gx, 1e-3, 1e-3) && gw.allclose(&want_gw, 1e-3, 1e-3) {
                Ok(())
            } else {
                Err(format!(
                    "geom {g:?}: gx diff {} gw diff {}",
                    gx.max_abs_diff(&want_gx),
                    gw.max_abs_diff(&want_gw)
                ))
            }
        },
    );
}

// ------------------------------------------------- thread-count bit-identity

/// Run `f` at pool widths 1, 2 and full; all results must be
/// bit-identical (the parallel determinism contract).
fn assert_thread_invariant(name: &str, f: impl Fn() -> NdArray) {
    let full = f();
    for limit in [1usize, 2] {
        let capped = parallel::with_thread_limit(limit, &f);
        assert_eq!(
            capped.data(),
            full.data(),
            "{name}: {limit}-thread result differs from {}-thread",
            parallel::num_threads()
        );
    }
}

#[test]
fn parallel_kernels_are_bit_identical_at_any_thread_count() {
    let mut rng = Rng::new(105);
    let a = rng.randn(&[200, 170], 1.0);
    let b = rng.randn(&[170, 130], 1.0);
    assert_thread_invariant("matmul", || ops::matmul(&a, &b));

    let ab = rng.randn(&[4, 40, 50], 1.0);
    let bb = rng.randn(&[4, 50, 30], 1.0);
    assert_thread_invariant("batch_matmul", || ops::batch_matmul(&ab, &bb));

    let x = rng.randn(&[2, 8, 24, 24], 1.0);
    let g = Conv2dGeom { kernel: (3, 3), stride: (1, 1), pad: (1, 1), dilation: (1, 1) };
    assert_thread_invariant("im2col", || ops::im2col(&x, &g));

    let cols = ops::im2col(&x, &g);
    assert_thread_invariant("col2im", || ops::col2im(&cols, x.dims(), &g));

    let w = rng.randn(&[12, 8, 3, 3], 1.0);
    let xv = Variable::from_array(x.clone(), false);
    let wv = Variable::from_array(w.clone(), false);
    assert_thread_invariant("conv forward", || {
        F::convolution(&xv, &wv, None, (1, 1), (1, 1), (1, 1)).data()
    });

    let big = rng.randn(&[64, 1024], 1.0);
    assert_thread_invariant("map", || ops::map(&big, |v| (v * 1.3).tanh()));
    assert_thread_invariant("zip", || ops::mul(&big, &big));
    assert_thread_invariant("sum_axis", || ops::sum_axis(&big, 1, false));
}

// --------------------------------------------------------- plan fast paths

fn conv_net(g: &Conv2dGeom, in_dims: &[usize]) -> NetworkDef {
    let net = NetworkDef {
        name: "convnet".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: in_dims.to_vec() }],
        outputs: vec!["y".into()],
        layers: vec![
            Layer {
                name: "conv".into(),
                op: Op::Convolution { stride: g.stride, pad: g.pad, dilation: g.dilation },
                inputs: vec!["x".into()],
                params: vec!["W".into(), "b".into()],
                outputs: vec!["h".into()],
            },
            Layer {
                name: "act".into(),
                op: Op::ReLU,
                inputs: vec!["h".into()],
                params: vec![],
                outputs: vec!["y".into()],
            },
        ],
    };
    net.validate().expect("well-formed test net");
    net
}

#[test]
fn plan_fast_path_is_bit_identical_to_tape() {
    let mut rng = Rng::new(106);
    let g = Conv2dGeom { kernel: (3, 3), stride: (2, 2), pad: (1, 1), dilation: (1, 1) };
    let x = rng.randn(&[2, 3, 12, 12], 1.0);
    let w = rng.randn(&[6, 3, 3, 3], 1.0);
    let b = rng.randn(&[6], 1.0);
    // tape path
    let xv = Variable::from_array(x.clone(), false);
    let wv = Variable::from_array(w.clone(), false);
    let bv = Variable::from_array(b.clone(), false);
    let tape_y = F::relu(&F::convolution(&xv, &wv, Some(&bv), g.stride, g.pad, g.dilation)).data();
    // compiled-plan path (fused fast path)
    let net = conv_net(&g, &[2, 3, 12, 12]);
    let mut params = HashMap::new();
    params.insert("W".to_string(), w);
    params.insert("b".to_string(), b);
    let plan = CompiledNet::compile(&net, &params).unwrap();
    let out = plan.execute_positional(&[x]).unwrap();
    assert_eq!(out[0].dims(), tape_y.dims());
    assert_eq!(out[0].data(), tape_y.data(), "plan conv fast path != tape");
    // and repeated execution (arena-recycled buffers) stays identical
    let mut named = HashMap::new();
    named.insert("x".to_string(), rng.randn(&[2, 3, 12, 12], 1.0));
    let r1 = plan.execute(&named).unwrap();
    let r2 = plan.execute(&named).unwrap();
    assert_eq!(r1[0].data(), r2[0].data());
}

#[test]
fn plan_affine_fast_path_is_bit_identical_to_tape() {
    let mut rng = Rng::new(107);
    let x = rng.randn(&[4, 20], 1.0);
    let w = rng.randn(&[20, 7], 1.0);
    let b = rng.randn(&[7], 1.0);
    let xv = Variable::from_array(x.clone(), false);
    let wv = Variable::from_array(w.clone(), false);
    let bv = Variable::from_array(b.clone(), false);
    let tape_y = F::affine(&xv, &wv, Some(&bv)).data();
    let net = NetworkDef {
        name: "fc".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: vec![4, 20] }],
        outputs: vec!["y".into()],
        layers: vec![Layer {
            name: "fc".into(),
            op: Op::Affine,
            inputs: vec!["x".into()],
            params: vec!["W".into(), "b".into()],
            outputs: vec!["y".into()],
        }],
    };
    let mut params = HashMap::new();
    params.insert("W".to_string(), w);
    params.insert("b".to_string(), b);
    let plan = CompiledNet::compile(&net, &params).unwrap();
    let out = plan.execute_positional(&[x]).unwrap();
    assert_eq!(out[0].data(), tape_y.data(), "plan affine fast path != tape");
}

#[test]
fn plan_rejects_degenerate_conv_geometry_cleanly() {
    // kernel bigger than the padded input must be an error, not a panic
    let g = Conv2dGeom { kernel: (9, 9), stride: (1, 1), pad: (0, 0), dilation: (1, 1) };
    let net = conv_net(&g, &[1, 3, 4, 4]);
    let mut params = HashMap::new();
    params.insert("W".to_string(), NdArray::zeros(&[2, 3, 9, 9]));
    params.insert("b".to_string(), NdArray::zeros(&[2]));
    let plan = CompiledNet::compile(&net, &params).unwrap();
    let err = plan.execute_positional(&[NdArray::zeros(&[1, 3, 4, 4])]).unwrap_err();
    assert!(err.contains("layer 'conv'"), "{err}");
    assert!(err.contains("kernel"), "{err}");
}

// ------------------------------------------------------------- SIMD tiers

/// Degenerate and off-grid shapes at every executable ISA: `k = 0`
/// (must be exact zeros — the accumulator never runs), `m = 1` /
/// `n = 1` (single-row/column panels), and shapes whose m/n/k are not
/// multiples of MR/NR/KC so every tail path in the vector kernels is
/// forced. All tiers are checked against the naive oracle.
#[test]
fn gemm_degenerate_shapes_match_naive_at_every_isa() {
    let mut rng = Rng::new(108);
    let shapes: [(usize, usize, usize); 9] = [
        (1, 0, 1),     // k = 0: empty reduction
        (3, 0, 5),     // k = 0 with a wider output
        (1, 1, 1),     // scalar product
        (1, 300, 130), // single row, big k/n (tiled path, n tail)
        (65, 600, 1),  // single column (tiled path, m tail)
        (9, 70, 65),   // m, n both off the 8-grid
        (65, 129, 33), // spans k blocks with tails everywhere
        (7, 1000, 9),  // sub-tile m/n, long k
        (64, 64, 64),  // exact-grid control
    ];
    for &(m, k, n) in &shapes {
        let a = if k == 0 { NdArray::zeros(&[m, k]) } else { rng.randn(&[m, k], 1.0) };
        let b = if k == 0 { NdArray::zeros(&[k, n]) } else { rng.randn(&[k, n], 1.0) };
        let want = ops::matmul_naive(&a, &b);
        for isa in dispatch::available_isas() {
            let got = dispatch::with_isa(isa, || ops::matmul(&a, &b));
            assert_eq!(got.dims(), want.dims());
            if k == 0 {
                assert!(
                    got.data().iter().all(|&v| v == 0.0),
                    "[{}] {m}x{k}·{k}x{n}: k=0 must give exact zeros",
                    isa.name()
                );
            } else {
                assert!(
                    got.allclose(&want, 1e-4, 1e-4),
                    "[{}] {m}x{k}·{k}x{n}: max diff {}",
                    isa.name(),
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

/// The numeric contract of the dispatched f32 tier: within 1e-5
/// relative of the scalar oracle over randomized shapes that straddle
/// the small/tiled cutoff. (FMA contracts rounding steps, so exact
/// equality is only promised per-ISA, not across tiers.)
#[test]
fn dispatched_gemm_stays_within_contract_of_scalar_oracle() {
    prop::check(
        109,
        16,
        |rng| {
            let m = 1 + rng.below(80);
            let k = 1 + rng.below(200);
            let n = 1 + rng.below(80);
            let a = rng.randn(&[m, k], 1.0);
            let b = rng.randn(&[k, n], 1.0);
            (a, b)
        },
        |(a, b)| {
            let oracle = dispatch::with_isa(Isa::Scalar, || ops::matmul(a, b));
            let got = ops::matmul(a, b); // dispatched tier
            if got.allclose(&oracle, 1e-5, 1e-6) {
                Ok(())
            } else {
                Err(format!(
                    "[{}] {}x{} · {}x{}: max diff {} vs scalar",
                    dispatch::isa().name(),
                    a.dims()[0],
                    a.dims()[1],
                    b.dims()[0],
                    b.dims()[1],
                    got.max_abs_diff(&oracle)
                ))
            }
        },
    );
}

/// The determinism contract holds per tier: at any fixed ISA, results
/// are bit-identical across pool widths (row shards never change the
/// per-element reduction order, vectorized or not).
#[test]
fn every_isa_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(110);
    let a = rng.randn(&[67, 190], 1.0);
    let b = rng.randn(&[190, 61], 1.0);
    for isa in dispatch::available_isas() {
        dispatch::with_isa(isa, || {
            assert_thread_invariant(&format!("matmul[{}]", isa.name()), || ops::matmul(&a, &b));
        });
    }
}

#[test]
fn isa_env_is_respected() {
    // CI pins NNL_ISA=scalar / NNL_ISA=auto; the process-wide dispatch
    // decision must honor the pin (falling back to scalar only when
    // the pinned tier is not executable on this machine).
    let dispatched = dispatch::isa();
    assert!(dispatch::available(dispatched), "dispatched ISA must be executable");
    let declared = std::env::var("NNL_ISA")
        .map(|v| v.trim().to_ascii_lowercase())
        .unwrap_or_default();
    match declared.as_str() {
        "scalar" => assert_eq!(dispatched, Isa::Scalar),
        "avx2" => {
            if dispatch::available(Isa::Avx2) {
                assert_eq!(dispatched, Isa::Avx2);
            } else {
                assert_eq!(dispatched, Isa::Scalar);
            }
        }
        "neon" => {
            if dispatch::available(Isa::Neon) {
                assert_eq!(dispatched, Isa::Neon);
            } else {
                assert_eq!(dispatched, Isa::Scalar);
            }
        }
        // unset / auto / unknown spelling: auto-detect, which always
        // lands on some executable tier (asserted above)
        _ => {}
    }
}

#[test]
fn thread_env_is_respected() {
    // NNL_THREADS=1 in CI must force a serial pool; otherwise ≥ 1
    let n = parallel::num_threads();
    let declared = std::env::var("NNL_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1);
    match declared {
        Some(want) => assert_eq!(n, want),
        None => assert!(n >= 1),
    }
}
