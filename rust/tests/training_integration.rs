//! Integration: end-to-end training across backends, checkpointing,
//! and cross-backend agreement.

use std::collections::HashMap;

use nnl::context::{Backend, Context, TypeConfig};
use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::models::Gb;
use nnl::nnp::Nnp;
use nnl::parametric as PF;
use nnl::runtime::Manifest;
use nnl::solvers::Solver;
use nnl::tensor::NdArray;
use nnl::trainer::{self, LossScalerKind, TrainConfig};
use nnl::Variable;

#[test]
fn lenet_dynamic_learns_and_beats_chance() {
    let data = SyntheticImages::new(10, 1, 28, 16, 5);
    let cfg = TrainConfig { steps: 50, lr: 0.02, val_batches: 4, ..Default::default() };
    let report = trainer::train_dynamic("lenet", &data, &cfg);
    let first = report.losses.points()[0].1;
    assert!(report.final_loss() < first * 0.8, "{first} -> {}", report.final_loss());
    assert!(report.val_error < 0.8, "val error {} vs chance 0.9", report.val_error);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn static_resnet_learns() {
    let Ok(manifest) = Manifest::load(&Manifest::default_dir()) else {
        assert!(!cfg!(feature = "pjrt"), "artifacts missing — run `make artifacts` first");
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return;
    };
    let data = SyntheticImages::imagenet_mini(16);
    let cfg = TrainConfig { steps: 60, lr: 0.05, ..Default::default() };
    let report =
        match trainer::train_static(&manifest, "resnet_mini_train_f32_b16", &data, &cfg) {
            Ok(r) => r,
            Err(e) => {
                assert!(!cfg!(feature = "pjrt"), "static runtime unavailable: {e}");
                eprintln!("skipping: static runtime unavailable: {e}");
                return;
            }
        };
    let first = report.losses.points()[0].1;
    assert!(report.final_loss() < first * 0.8, "{first} -> {}", report.final_loss());
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn static_mixed_precision_with_dynamic_scaler() {
    let Ok(manifest) = Manifest::load(&Manifest::default_dir()) else {
        assert!(!cfg!(feature = "pjrt"), "artifacts missing — run `make artifacts` first");
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return;
    };
    let data = SyntheticImages::imagenet_mini(16);
    let cfg = TrainConfig {
        steps: 40,
        lr: 0.05,
        loss_scale: Some(LossScalerKind::Dynamic { initial: 1024.0, factor: 2.0, interval: 50 }),
        ..Default::default()
    };
    let report =
        match trainer::train_static(&manifest, "resnet_mini_train_bf16_b16", &data, &cfg) {
            Ok(r) => r,
            Err(e) => {
                assert!(!cfg!(feature = "pjrt"), "static runtime unavailable: {e}");
                eprintln!("skipping: static runtime unavailable: {e}");
                return;
            }
        };
    let first = report.losses.points()[0].1;
    assert!(
        report.final_loss() < first,
        "mixed precision diverged: {first} -> {}",
        report.final_loss()
    );
}

#[test]
fn half_context_quantizes_parameters() {
    Context::set_default(Context::new(Backend::Cpu, TypeConfig::Half));
    PF::clear_parameters();
    PF::seed_parameter_rng(1);
    let mut g = Gb::new("m", true);
    let x = g.input("x", &[1, 8]);
    let _ = g.affine(&x, 4, "fc");
    let (_, w) = PF::get_parameters().into_iter().next().unwrap();
    assert_eq!(w.data().dtype(), nnl::tensor::DType::BF16);
    Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
    PF::clear_parameters();
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    // train briefly, save to NNP, reload from disk, verify identical
    // eval outputs (the deployment workflow of Figure 2)
    let data = SyntheticImages::new(4, 1, 8, 8, 11);
    PF::clear_parameters();
    PF::seed_parameter_rng(2);
    {
        let mut g = Gb::new("mlp8", true);
        let x = g.input("x", &[8, 64]);
        let h = g.affine(&x, 32, "fc1");
        let h = g.relu(&h);
        let logits = g.affine(&h, 4, "out");
        let y = Variable::new(&[8, 1], false);
        let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
        let mut solver = Solver::momentum(0.1, 0.9);
        solver.set_parameters(&PF::get_parameters());
        for step in 0..20 {
            let (bx, by) = data.batch(step, 0, 1);
            x.var.set_data(bx.reshape(&[8, 64]));
            y.set_data(by.reshape(&[8, 1]));
            loss.forward();
            solver.zero_grad();
            loss.backward();
            solver.update();
        }
    }
    // export eval-mode graph with the trained params
    let mut ge = Gb::new("mlp8", false);
    let xe = ge.input("x", &[8, 64]);
    let he = ge.affine(&xe, 32, "fc1");
    let he = ge.relu(&he);
    let le = ge.affine(&he, 4, "out");
    let def = ge.finish(&[&le]);
    let params: Vec<(String, NdArray)> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let nnp = Nnp::from_network(def, params);

    let dir = std::env::temp_dir().join(format!("nnl_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.nnp");
    nnp.save(&path).unwrap();

    let (bx, _) = data.val_batch(0);
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), bx.reshape(&[8, 64]));
    let before = nnp.execute("mlp8_executor", &inputs).unwrap();
    let loaded = Nnp::load(&path).unwrap();
    let after = loaded.execute("mlp8_executor", &inputs).unwrap();
    assert_eq!(before[0].data(), after[0].data(), "checkpoint changed numerics");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_model_panics_cleanly() {
    let result = std::panic::catch_unwind(|| {
        let data = SyntheticImages::imagenet_mini(4);
        let cfg = TrainConfig { steps: 1, ..Default::default() };
        trainer::train_dynamic("not_a_model", &data, &cfg)
    });
    assert!(result.is_err());
}

#[test]
fn distributed_training_is_finite_and_learns() {
    let data = SyntheticImages::new(4, 3, 16, 8, 13);
    let cfg = TrainConfig {
        steps: 8,
        lr: 0.02,
        solver: "sgd".into(),
        val_batches: 0,
        ..Default::default()
    };
    let dist = trainer::train_distributed("resnet18", data, &cfg, 2);
    assert!(dist.losses.points().iter().all(|(_, l)| l.is_finite()));
    let d0 = dist.losses.points()[0].1;
    assert!(dist.final_loss() < d0 * 1.2);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "static PJRT runtime not built (enable the `pjrt` feature)")]
fn static_train_then_static_eval_improves_accuracy() {
    // full loop: train artifact + matching infer artifact
    let Ok(manifest) = Manifest::load(&Manifest::default_dir()) else {
        assert!(!cfg!(feature = "pjrt"), "artifacts missing — run `make artifacts` first");
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return;
    };
    let data = SyntheticImages::imagenet_mini(16);
    // fresh-init accuracy
    let spec = manifest.get("resnet_mini_train_f32_b16").unwrap().clone();
    let init: Vec<NdArray> = spec.init_params().into_iter().map(|(_, a)| a).collect();
    let before =
        match trainer::evaluate_static(&manifest, "resnet_mini_infer_f32_b16", &init, &data, 4) {
            Ok(v) => v,
            Err(e) => {
                assert!(!cfg!(feature = "pjrt"), "static runtime unavailable: {e}");
                eprintln!("skipping: static runtime unavailable: {e}");
                return;
            }
        };
    // train
    let cfg = TrainConfig { steps: 80, lr: 0.05, ..Default::default() };
    let _report =
        trainer::train_static(&manifest, "resnet_mini_train_f32_b16", &data, &cfg).unwrap();
    // NOTE: train_static owns its params; retrain here inline to get them
    let exe =
        match nnl::runtime::StaticExecutable::load(&manifest, "resnet_mini_train_f32_b16") {
            Ok(exe) => exe,
            Err(e) => {
                assert!(!cfg!(feature = "pjrt"), "static runtime unavailable: {e}");
                eprintln!("skipping: static runtime unavailable: {e}");
                return;
            }
        };
    let mut params: Vec<NdArray> =
        exe.spec().init_params().into_iter().map(|(_, a)| a).collect();
    let mut solver = Solver::momentum(0.05, 0.9);
    let vars: Vec<(String, Variable)> = params
        .iter()
        .enumerate()
        .map(|(i, a)| (format!("p{i}"), Variable::from_array(a.clone(), true)))
        .collect();
    solver.set_parameters(&vars);
    for step in 0..80 {
        let (bx, by) = data.batch(step, 0, 1);
        let mut inputs: Vec<NdArray> = vars.iter().map(|(_, v)| v.data()).collect();
        inputs.push(bx);
        inputs.push(by);
        inputs.push(NdArray::scalar(1.0));
        let out = exe.execute(&inputs).unwrap();
        for ((_, v), g) in vars.iter().zip(&out[..vars.len()]) {
            v.set_grad(g.clone());
        }
        solver.update();
    }
    params = vars.iter().map(|(_, v)| v.data()).collect();
    let after =
        trainer::evaluate_static(&manifest, "resnet_mini_infer_f32_b16", &params, &data, 4)
            .unwrap();
    assert!(
        after < before,
        "training did not improve static eval accuracy: {before} -> {after}"
    );
    assert!(after < 0.6, "post-training error {after} (chance 0.9)");
}
