//! Acceptance suite for the static verifier (`nnp::verify` / `nnl
//! check`):
//!
//! - every zoo model passes `check_model` error-free, which internally
//!   compiles at O0/O1/O2 and runs translation validation on each plan;
//! - a well-formed artifact carrying an inconsistent weight is flagged
//!   with the stable shape code `NNL-E006`;
//! - `check_artifact` never panics on corrupted bytes: random bit
//!   flips and truncations of real NNB1/NNB2 images (seeded via
//!   `utils::prop`) must come back as `Err` (undecodable) or a
//!   `Report` (decodable, possibly diagnosed) — anything else is a
//!   crash a hostile DEPLOY payload could trigger in the server.

use std::collections::HashMap;

use nnl::bench_quant::random_inputs;
use nnl::converters::nnb;
use nnl::models::zoo;
use nnl::nnp::verify;
use nnl::quant::{quantize_net, QuantConfig};
use nnl::tensor::{NdArray, Rng};

#[test]
fn every_zoo_model_checks_clean_at_all_levels() {
    for name in zoo::model_names() {
        let (net, params) = zoo::export_eval(name, 11);
        let report = verify::check_model(&net, &params);
        assert!(
            !report.has_errors(),
            "{name}: static verification found errors:\n{}",
            report.render_human()
        );
    }
}

#[test]
fn corrupted_weight_in_artifact_is_flagged_e006() {
    let (net, params) = zoo::export_eval("mlp", 3);
    let mut params: Vec<(String, NdArray)> = params.into_iter().collect();
    let idx = params
        .iter()
        .position(|(_, a)| a.dims().len() == 2)
        .expect("mlp has a rank-2 weight");
    let d = params[idx].1.dims().to_vec();
    params[idx].1 = NdArray::zeros(&[d[0] + 1, d[1]]);
    let image = nnb::to_nnb(&net, &params);
    let report = verify::check_artifact(&image).expect("image still decodes");
    assert!(report.has_errors());
    assert!(
        report.has_code(verify::codes::SHAPE_MISMATCH),
        "want NNL-E006, got:\n{}",
        report.render_human()
    );
}

/// Flip one bit somewhere in `image` and run the checker; the property
/// is only that it terminates with a `Result`, never a panic. (The
/// decoder is length-guarded throughout, so a flipped count or length
/// field must surface as `Err("truncated NNB")`-style decode failures.)
fn flip_and_check(image: &[u8], seed: u64, cases: usize) {
    nnl::utils::prop::check(
        seed,
        cases,
        |rng| (rng.below(image.len()), rng.below(8) as u8),
        |&(pos, bit)| {
            let mut bytes = image.to_vec();
            bytes[pos] ^= 1 << bit;
            match verify::check_artifact(&bytes) {
                Ok(report) => {
                    // decodable: the report must also serialize (the
                    // CLI's --json path) without panicking
                    let _ = report.to_json().to_string();
                    let _ = report.render_human();
                    Ok(())
                }
                Err(_) => Ok(()), // undecodable is a fine answer
            }
        },
    );
}

fn truncate_and_check(image: &[u8], seed: u64, cases: usize) {
    nnl::utils::prop::check(
        seed,
        cases,
        |rng| rng.below(image.len()),
        |&keep| {
            match verify::check_artifact(&image[..keep]) {
                Ok(report) => {
                    let _ = report.render_human();
                    Ok(())
                }
                Err(_) => Ok(()),
            }
        },
    );
}

#[test]
fn bit_flipped_nnb1_never_panics_the_checker() {
    let (net, params) = zoo::export_eval("mlp", 7);
    let image = nnb::to_nnb(&net, &params.into_iter().collect::<Vec<_>>());
    // pristine image is clean
    let report = verify::check_artifact(&image).expect("pristine image decodes");
    assert!(!report.has_errors(), "{}", report.render_human());
    flip_and_check(&image, 17, 48);
    truncate_and_check(&image, 18, 16);
}

#[test]
fn bit_flipped_nnb2_never_panics_the_checker() {
    let (net, params) = zoo::export_eval("mlp", 7);
    let params: HashMap<String, NdArray> = params.into_iter().collect();
    let calib = random_inputs(&net, 4, &mut Rng::new(9));
    let (model, _) =
        quantize_net(&net, &params, &calib, &QuantConfig::default()).expect("mlp quantizes");
    let image = nnb::to_nnb2(&model);
    let report = verify::check_artifact(&image).expect("pristine NNB2 decodes");
    assert!(!report.has_errors(), "{}", report.render_human());
    flip_and_check(&image, 19, 48);
    truncate_and_check(&image, 20, 16);
}
