//! Integration: the self-describing tape. Graphs built *only* from
//! `F::*` / `PF::*` calls (no builder) round-trip
//! `trace` → `NetworkDef` → interpreter with outputs **bit-identical**
//! to the live graph, and traced attributes survive the ONNX round
//! trip — the acceptance criteria of the Function-descriptor redesign.

use std::collections::HashMap;

use nnl::converters::onnx_lite;
use nnl::functions as F;
use nnl::nnp::{interpreter, trace, Op};
use nnl::parametric as PF;
use nnl::tensor::{NdArray, Rng};
use nnl::Variable;

fn reset(seed: u64) {
    PF::clear_parameters();
    PF::seed_parameter_rng(seed);
}

fn registry_params() -> HashMap<String, NdArray> {
    PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect()
}

/// LeNet exactly as Listing 4, but with raw `F::*`/`PF::*` calls — no
/// `Gb` anywhere.
fn lenet_functional(x: &Variable) -> Variable {
    let h = PF::convolution(x, 16, (5, 5), (1, 1), (0, 0), "conv1");
    let h = F::max_pooling(&h, (2, 2), (2, 2), (0, 0));
    let h = F::relu(&h);
    let h = PF::convolution(&h, 16, (5, 5), (1, 1), (0, 0), "conv2");
    let h = F::max_pooling(&h, (2, 2), (2, 2), (0, 0));
    let h = F::relu(&h);
    let h = PF::affine(&h, 50, "affine3");
    let h = F::relu(&h);
    PF::affine(&h, 10, "affine4")
}

#[test]
fn lenet_built_without_gb_roundtrips_bit_identical() {
    reset(101);
    let mut rng = Rng::new(7);
    let input = rng.randn(&[2, 1, 28, 28], 1.0);
    let x = Variable::from_array(input.clone(), false);
    x.set_name("x");
    let y = lenet_functional(&x);

    let def = trace("lenet_fn", &[&y]).unwrap();
    assert!(def.validate().is_ok());
    assert_eq!(def.inputs[0].name, "x");
    // all four parametric layers present with scope-derived names
    for lname in ["conv1", "conv2", "affine3", "affine4"] {
        assert!(def.layers.iter().any(|l| l.name == lname), "missing layer {lname}");
    }

    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input);
    let out = interpreter::run(&def, &inputs, &registry_params()).unwrap();
    assert_eq!(
        out[0].data(),
        y.data().data(),
        "trace→NetworkDef→interpreter must be bit-identical to the live tape"
    );
}

#[test]
fn mlp_built_without_gb_roundtrips_bit_identical() {
    reset(102);
    let mut rng = Rng::new(8);
    let input = rng.randn(&[4, 32], 1.0);
    let x = Variable::from_array(input.clone(), false);
    x.set_name("x");
    let h = PF::affine(&x, 64, "fc1");
    let h = F::relu(&h);
    let h = F::dropout_inference(&h, 0.1); // eval-mode dropout, recorded
    let h = PF::affine(&h, 16, "fc2");
    let h = F::relu(&h);
    let y = PF::affine(&h, 10, "out");

    let def = trace("mlp_fn", &[&y]).unwrap();
    assert!(def.layers.iter().any(|l| matches!(l.op, Op::Dropout { .. })));

    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input);
    let out = interpreter::run(&def, &inputs, &registry_params()).unwrap();
    assert_eq!(out[0].data(), y.data().data());
}

#[test]
fn traced_graph_is_batch_size_flexible() {
    reset(103);
    let x = Variable::new(&[4, 32], false);
    x.set_name("x");
    let h = PF::affine(&x, 8, "fc");
    let y = F::relu(&h);
    let def = trace("flex", &[&y]).unwrap();
    // run the traced net at a different batch size
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), NdArray::zeros(&[9, 32]));
    let out = interpreter::run(&def, &inputs, &registry_params()).unwrap();
    assert_eq!(out[0].dims(), &[9, 8]);
}

#[test]
fn trace_to_onnx_preserves_conv_pool_norm_attributes() {
    reset(104);
    let x = Variable::new(&[1, 3, 16, 16], false);
    x.set_name("x");
    let h = PF::convolution(&x, 4, (3, 3), (2, 1), (1, 2), "c1");
    let h = PF::batch_normalization(&h, false, "bn1");
    let h = F::relu(&h);
    let h = F::max_pooling(&h, (2, 2), (2, 2), (0, 0));
    let h = F::average_pooling(&h, (3, 3), (1, 1), (1, 1), true);
    let y = F::global_average_pooling(&h);

    let def = trace("attrs", &[&y]).unwrap();
    let onnx = onnx_lite::to_onnx(&def, &registry_params()).unwrap();
    let (def2, _) = onnx_lite::from_onnx(&onnx).unwrap();

    // conv / pool / norm attributes survive trace → ONNX → trace
    let find = |d: &nnl::nnp::NetworkDef, pred: fn(&Op) -> bool| -> Op {
        d.layers.iter().find(|l| pred(&l.op)).expect("op missing").op.clone()
    };
    let conv = |o: &Op| matches!(o, Op::Convolution { .. });
    let maxp = |o: &Op| matches!(o, Op::MaxPool { .. });
    let avgp = |o: &Op| matches!(o, Op::AvgPool { .. });
    let bn = |o: &Op| matches!(o, Op::BatchNorm { .. });
    assert_eq!(find(&def, conv), find(&def2, conv));
    assert_eq!(
        find(&def, conv),
        Op::Convolution { stride: (2, 1), pad: (1, 2), dilation: (1, 1) }
    );
    assert_eq!(find(&def, maxp), find(&def2, maxp));
    assert_eq!(find(&def, avgp), find(&def2, avgp));
    assert_eq!(find(&def, bn), find(&def2, bn));
    assert_eq!(find(&def, bn), Op::BatchNorm { eps: 1e-5 });
}

#[test]
fn traced_residual_block_roundtrips() {
    // diamond topology: shared input, add-join — the shape trace has to
    // get right for ResNets
    reset(105);
    let mut rng = Rng::new(9);
    let input = rng.randn(&[2, 4, 8, 8], 1.0);
    let x = Variable::from_array(input.clone(), false);
    x.set_name("x");
    let r = PF::convolution(&x, 4, (3, 3), (1, 1), (1, 1), "c1");
    let r = F::relu(&r);
    let y = F::relu(&F::add(&r, &x));

    let def = trace("res", &[&y]).unwrap();
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input);
    let out = interpreter::run(&def, &inputs, &registry_params()).unwrap();
    assert_eq!(out[0].data(), y.data().data());
}
