//! Pass-pipeline parity suite (CI re-runs it under `NNL_THREADS=1`):
//!
//! - for every zoo model, the optimized plan matches the unoptimized
//!   interpreter-semantics plan — bit-identical at O1 (elision / DCE /
//!   fusion share the exact kernels), ≤ 1e-4 relative at O2 (BN /
//!   const folding re-associate floats);
//! - thread-count bit-identity is preserved under `with_thread_limit`;
//! - the static memory plan's peak never exceeds the naive
//!   sum-of-slot-sizes bound and never grows under optimization;
//! - `interpreter::run` (and everything built on it: converters,
//!   trace round-trips, training-side comparisons) stays at O0 —
//!   provably untouched by optimizer semantics;
//! - NNB2 calibrate → quantize → serve stays consistent under
//!   optimization: ranges exist for exactly the tensors the optimized
//!   plan materializes, and roundtripped artifacts agree.

use std::collections::{HashMap, HashSet};

use nnl::bench_quant::random_inputs;
use nnl::converters::nnb;
use nnl::models::zoo;
use nnl::nnp::passes::{optimize, OptLevel};
use nnl::nnp::{interpreter, CompiledNet, InferencePlan, Layer, NetworkDef, Op, TensorDef};
use nnl::quant::{quantize_net, QuantConfig};
use nnl::tensor::{parallel, NdArray, Rng};

#[test]
fn optimized_zoo_plans_match_unoptimized() {
    for (mi, name) in zoo::model_names().into_iter().enumerate() {
        let (net, params) = zoo::export_eval(name, 11);
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0)
            .unwrap_or_else(|e| panic!("{name} O0: {e}"));
        let p1 = CompiledNet::compile_with(&net, &params, OptLevel::O1)
            .unwrap_or_else(|e| panic!("{name} O1: {e}"));
        let p2 = CompiledNet::compile(&net, &params)
            .unwrap_or_else(|e| panic!("{name} O2: {e}"));
        assert!(p1.n_steps() <= p0.n_steps(), "{name}: O1 grew the plan");
        assert!(p2.n_steps() <= p1.n_steps(), "{name}: O2 grew the plan");
        for s in random_inputs(&net, 2, &mut Rng::new(40 + mi as u64)) {
            let o0 = p0.execute_positional(&s).unwrap();
            let o1 = p1.execute_positional(&s).unwrap();
            let o2 = p2.execute_positional(&s).unwrap();
            for ((a, b), c) in o0.iter().zip(&o1).zip(&o2) {
                assert_eq!(a.dims(), b.dims(), "{name}: O1 changed shapes");
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{name}: O1 must be bit-identical (shared kernels)"
                );
                assert!(
                    a.allclose(c, 1e-4, 1e-4),
                    "{name}: O2 drifted by {}",
                    a.max_abs_diff(c)
                );
            }
        }
    }
}

#[test]
fn optimized_plans_are_bit_identical_at_any_thread_count() {
    for name in ["lenet", "resnet18"] {
        let (net, params) = zoo::export_eval(name, 11);
        let plan = CompiledNet::compile(&net, &params).unwrap();
        for s in random_inputs(&net, 3, &mut Rng::new(51)) {
            let full = plan.execute_positional(&s).unwrap();
            let serial = parallel::with_thread_limit(1, || plan.execute_positional(&s).unwrap());
            for (a, b) in full.iter().zip(&serial) {
                assert_eq!(a.dims(), b.dims());
                assert_eq!(a.data(), b.data(), "{name}: thread count changed optimized bits");
            }
        }
    }
}

#[test]
fn planned_peak_bytes_are_bounded_and_never_grow_under_optimization() {
    for name in zoo::model_names() {
        let (net, params) = zoo::export_eval(name, 11);
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        let p2 = CompiledNet::compile(&net, &params).unwrap();
        let m0 = p0.memory_plan().unwrap_or_else(|| panic!("{name}: no O0 memory plan"));
        let m2 = p2.memory_plan().unwrap_or_else(|| panic!("{name}: no O2 memory plan"));
        for m in [m0, m2] {
            assert!(m.peak_bytes > 0, "{name}: empty arena");
            assert!(
                m.peak_bytes <= m.naive_bytes,
                "{name}: peak {} exceeds naive {}",
                m.peak_bytes,
                m.naive_bytes
            );
            let largest =
                m.slots.iter().flatten().map(|a| a.bytes).max().unwrap_or(0);
            assert!(m.peak_bytes >= largest, "{name}: peak below largest slot");
        }
        assert!(
            m2.peak_bytes <= m0.peak_bytes,
            "{name}: optimization grew peak bytes ({} -> {})",
            m0.peak_bytes,
            m2.peak_bytes
        );
    }
}

/// A hand-built conv → BN → relu net — the shape BN folding targets.
fn conv_bn_relu() -> (NetworkDef, HashMap<String, NdArray>) {
    let net = NetworkDef {
        name: "cbr".into(),
        inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2, 6, 6] }],
        outputs: vec!["y".into()],
        layers: vec![
            Layer {
                name: "conv".into(),
                op: Op::Convolution { stride: (1, 1), pad: (1, 1), dilation: (1, 1) },
                inputs: vec!["x".into()],
                params: vec!["W".into(), "b".into()],
                outputs: vec!["h".into()],
            },
            Layer {
                name: "bn".into(),
                op: Op::BatchNorm { eps: 1e-5 },
                inputs: vec!["h".into()],
                params: vec!["beta".into(), "gamma".into(), "mean".into(), "var".into()],
                outputs: vec!["hb".into()],
            },
            Layer {
                name: "act".into(),
                op: Op::ReLU,
                inputs: vec!["hb".into()],
                params: vec![],
                outputs: vec!["y".into()],
            },
        ],
    };
    let mut rng = Rng::new(61);
    let mut params = HashMap::new();
    params.insert("W".to_string(), rng.randn(&[4, 2, 3, 3], 0.5));
    params.insert("b".to_string(), rng.randn(&[4], 0.2));
    params.insert("beta".to_string(), rng.randn(&[4], 0.3));
    params.insert("gamma".to_string(), rng.rand(&[4], 0.5, 1.5));
    params.insert("mean".to_string(), rng.randn(&[4], 0.4));
    params.insert("var".to_string(), rng.rand(&[4], 0.2, 1.2));
    (net, params)
}

#[test]
fn interpreter_runs_at_o0_untouched_by_optimizer_semantics() {
    let (net, params) = conv_bn_relu();
    let x = Rng::new(62).randn(&[2, 2, 6, 6], 1.0);
    let mut named = HashMap::new();
    named.insert("x".to_string(), x.clone());
    // the interpreter executes the graph exactly as written: its
    // output is bit-identical to an explicit O0 plan even though the
    // O2 pipeline would fold the BN away
    let interp = interpreter::run(&net, &named, &params).unwrap();
    let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
    let o0 = p0.execute_positional(&[x.clone()]).unwrap();
    assert_eq!(interp[0].data(), o0[0].data(), "interpreter must stay at O0");
    assert_eq!(p0.n_steps(), 3);
    // while the default pipeline really does rewrite this graph
    let p2 = CompiledNet::compile(&net, &params).unwrap();
    assert_eq!(p2.n_steps(), 1, "conv+bn+relu must fold+fuse into one step");
    let o2 = p2.execute_positional(&[x]).unwrap();
    assert!(o0[0].allclose(&o2[0], 1e-4, 1e-4));
}

#[test]
fn calibration_covers_exactly_the_materialized_tensors() {
    let (net, params) = zoo::export_eval("mlp", 11);
    let samples = random_inputs(&net, 8, &mut Rng::new(71));
    let (model, _) = quantize_net(&net, &params, &samples, &QuantConfig::default()).unwrap();
    // what the optimized plan actually materializes
    let (onet, oparams, _) = optimize(&net, &params, OptLevel::default()).unwrap();
    let plan = CompiledNet::compile(&onet, &oparams).unwrap();
    let mut observed: HashSet<String> = HashSet::new();
    plan.execute_observed(&samples[0], &mut |name, _| {
        observed.insert(name.to_string());
    })
    .unwrap();
    for (name, _) in &model.calib.ranges {
        assert!(observed.contains(name), "calibrated '{name}' is not materialized");
    }
    assert_eq!(model.calib.ranges.len(), observed.len());
    // and the unoptimized plan materializes strictly more (dropout +
    // pre-ReLU affine outputs exist only at O0)
    let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
    let mut observed0: HashSet<String> = HashSet::new();
    p0.execute_observed(&samples[0], &mut |name, _| {
        observed0.insert(name.to_string());
    })
    .unwrap();
    assert!(
        observed.len() < observed0.len(),
        "optimizer materialized nothing less ({} vs {})",
        observed.len(),
        observed0.len()
    );
}

#[test]
fn nnb2_agreement_is_unchanged_by_roundtrip() {
    for name in ["mlp", "lenet"] {
        let (net, params) = zoo::export_eval(name, 11);
        let samples = random_inputs(&net, 8, &mut Rng::new(73));
        let (model, qnet) =
            quantize_net(&net, &params, &samples, &QuantConfig::default()).unwrap();
        let bytes = nnb::to_nnb2(&model);
        let engine = nnb::NnbEngine::load(&bytes).unwrap();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let evals = random_inputs(&net, 32, &mut Rng::new(74));
        let mut agree_mem = 0usize;
        let mut agree_disk = 0usize;
        for s in &evals {
            let f = plan.execute_positional(s).unwrap();
            let q_mem = qnet.execute_positional(s).unwrap();
            let q_disk = engine.plan().execute_positional(s).unwrap();
            // serve agreement is unchanged by serialization: the
            // roundtripped plan is bit-identical to the in-memory one
            assert_eq!(q_mem[0].data(), q_disk[0].data(), "{name}: roundtrip drifted");
            if f[0].argmax_flat() == q_mem[0].argmax_flat() {
                agree_mem += 1;
            }
            if f[0].argmax_flat() == q_disk[0].argmax_flat() {
                agree_disk += 1;
            }
        }
        assert_eq!(agree_mem, agree_disk);
        assert!(
            agree_mem * 100 >= evals.len() * 90,
            "{name}: agreement {agree_mem}/{}",
            evals.len()
        );
    }
}
