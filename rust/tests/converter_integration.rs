//! Integration: the full Figure 2 fabric on a *deep* zoo model
//! (SE-ResNeXt exercises grouped conv, SE gates, residuals) — every
//! format must reproduce the source network's inference bit-for-bit
//! (within f32 tolerance).

use std::collections::HashMap;

use nnl::converters::{frozen, nnb, onnx_lite, query};
use nnl::models::{build_model, Gb};
use nnl::nnp::{interpreter, Nnp};
use nnl::parametric as PF;
use nnl::tensor::{NdArray, Rng};

fn export_model(name: &str, dims: &[usize]) -> (nnl::nnp::NetworkDef, Vec<(String, NdArray)>) {
    PF::clear_parameters();
    PF::seed_parameter_rng(17);
    let mut g = Gb::new(name, false);
    let x = g.input("x", dims);
    let logits = build_model(&mut g, name, &x, 10);
    let def = g.finish(&[&logits]);
    let params: Vec<(String, NdArray)> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    (def, params)
}

fn reference_output(
    def: &nnl::nnp::NetworkDef,
    params: &[(String, NdArray)],
    input: &NdArray,
) -> NdArray {
    let pm: HashMap<String, NdArray> = params.iter().cloned().collect();
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input.clone());
    interpreter::run(def, &inputs, &pm).unwrap().remove(0)
}

#[test]
fn se_resnext_roundtrips_through_every_format() {
    let dims = [2usize, 3, 16, 16];
    let (def, params) = export_model("se_resnext50", &dims);
    let mut rng = Rng::new(3);
    let input = rng.randn(&dims, 1.0);
    let reference = reference_output(&def, &params, &input);
    let pm: HashMap<String, NdArray> = params.iter().cloned().collect();
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input.clone());

    // NNP save/load
    let dir = std::env::temp_dir().join(format!("nnl_convint_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let nnp = Nnp::from_network(def.clone(), params.clone());
    let path = dir.join("m.nnp");
    nnp.save(&path).unwrap();
    let loaded = Nnp::load(&path).unwrap();
    let via_nnp = loaded.execute("se_resnext50_executor", &inputs).unwrap().remove(0);
    assert!(reference.allclose(&via_nnp, 1e-4, 1e-4), "NNP roundtrip diverged");

    // ONNX roundtrip
    let onnx = onnx_lite::to_onnx(&def, &pm).unwrap();
    let bytes = onnx_lite::save_bytes(&onnx);
    let onnx2 = onnx_lite::load_bytes(&bytes).unwrap();
    let (net2, params2) = onnx_lite::from_onnx(&onnx2).unwrap();
    let pm2: HashMap<String, NdArray> = params2.into_iter().collect();
    let via_onnx = interpreter::run(&net2, &inputs, &pm2).unwrap().remove(0);
    assert!(reference.allclose(&via_onnx, 1e-4, 1e-4), "ONNX roundtrip diverged");

    // NNB execution
    let nnb_bytes = nnb::to_nnb(&def, &params);
    let via_nnb = nnb::run_nnb(&nnb_bytes, &inputs).unwrap().remove(0);
    assert!(reference.allclose(&via_nnb, 1e-4, 1e-4), "NNB diverged");

    // frozen graph
    let fg = frozen::freeze(&def, &pm).unwrap();
    let fg2 = frozen::load_bytes(&frozen::save_bytes(&fg)).unwrap();
    let via_frozen = frozen::run(&fg2, &inputs).unwrap().remove(0);
    assert!(reference.allclose(&via_frozen, 1e-4, 1e-4), "frozen diverged");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn query_predicts_onnx_conversion_outcome() {
    // mobilenet uses Swish -> query must flag it and conversion must
    // fail with the same function name; resnet18 (ReLU only) passes
    let (mb_def, mb_params) = export_model("mobilenet_v3_small", &[1, 3, 16, 16]);
    let gaps = query::query_unsupported(&mb_def, query::Target::OnnxLite);
    assert_eq!(gaps, vec!["Swish"]);
    let pm: HashMap<String, NdArray> = mb_params.iter().cloned().collect();
    let err = onnx_lite::to_onnx(&mb_def, &pm).unwrap_err();
    assert!(err.to_string().contains("Swish"));

    let (rn_def, rn_params) = export_model("resnet18", &[1, 3, 16, 16]);
    assert!(query::query_unsupported(&rn_def, query::Target::OnnxLite).is_empty());
    let pm: HashMap<String, NdArray> = rn_params.iter().cloned().collect();
    assert!(onnx_lite::to_onnx(&rn_def, &pm).is_ok());
}

#[test]
fn nnp_halves_on_disk_with_bf16_params() {
    let (def, params) = export_model("resnet18", &[1, 3, 16, 16]);
    let dir = std::env::temp_dir().join(format!("nnl_half_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let f32_path = dir.join("f32.nnp");
    Nnp::from_network(def.clone(), params.clone()).save(&f32_path).unwrap();

    let half_params: Vec<(String, NdArray)> = params
        .iter()
        .map(|(n, a)| (n.clone(), a.cast(nnl::tensor::DType::BF16)))
        .collect();
    let half_path = dir.join("half.nnp");
    Nnp::from_network(def, half_params).save(&half_path).unwrap();

    let f32_size = std::fs::metadata(&f32_path).unwrap().len();
    let half_size = std::fs::metadata(&half_path).unwrap().len();
    // paper §3.3: "nearly halves the memory usage"
    assert!(
        (half_size as f64) < f32_size as f64 * 0.62,
        "half checkpoint not ~half size: {half_size} vs {f32_size}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn all_zoo_models_convert_to_nnb_and_execute() {
    for name in ["mlp", "lenet", "resnet18", "mobilenet_v3_small", "efficientnet_b0"] {
        let dims: Vec<usize> = match name {
            "mlp" => vec![2, 64],
            "lenet" => vec![2, 1, 28, 28],
            _ => vec![2, 3, 16, 16],
        };
        let (def, params) = export_model(name, &dims);
        let mut rng = Rng::new(1);
        let input = rng.randn(&dims, 1.0);
        let reference = reference_output(&def, &params, &input);
        let nnb_bytes = nnb::to_nnb(&def, &params);
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), input);
        let out = nnb::run_nnb(&nnb_bytes, &inputs).unwrap().remove(0);
        assert!(
            reference.allclose(&out, 1e-4, 1e-4),
            "{name}: NNB disagrees (max diff {})",
            reference.max_abs_diff(&out)
        );
    }
}
