//! Integration tests for multi-process distributed data-parallel
//! training over the TCP ring (`comm::net` + `trainer::train_worker`).
//!
//! The headline invariant: an N-process `nnl train-dist --launch N`
//! run over loopback produces, at EVERY rank, final parameters
//! **bit-identical** to `trainer::train_distributed_reference` — a
//! sequential single-process simulation of the same fold. fp16 wire
//! compression relaxes that to a small tolerance but must stay
//! deterministic across reruns. Codec and bucket-plan properties ride
//! along, plus (under `--features chaos`) the dropped-peer guarantee:
//! typed errors at every rank, never a hang.

use std::path::PathBuf;
use std::process::Command;

use nnl::data::SyntheticImages;
use nnl::tensor::Rng;
use nnl::trainer::{read_params_dump, train_distributed_reference, TrainConfig};
use nnl::utils::prop;

/// The training job every test in this file runs: lenet (no dropout,
/// no BN — per-rank randomness would break bit-exactness by design),
/// batch 8, 4 steps. Mirrors the `nnl train-dist` defaults it spawns.
fn job_cfg() -> TrainConfig {
    TrainConfig { steps: 4, val_batches: 1, ..Default::default() }
}

fn job_data() -> SyntheticImages {
    SyntheticImages::new(10, 1, 28, 8, 1)
}

/// Run `nnl train-dist --launch <world>` over loopback, dumping every
/// rank's final parameters into `dir`. Extra flags appended verbatim.
fn launch_train_dist(world: usize, dir: &PathBuf, extra: &[&str]) {
    std::fs::create_dir_all(dir).expect("create dump dir");
    let cfg = job_cfg();
    let out = Command::new(env!("CARGO_BIN_EXE_nnl"))
        .args([
            "train-dist",
            "--launch",
            &world.to_string(),
            "--model",
            "lenet",
            "--steps",
            &cfg.steps.to_string(),
            "--batch",
            "8",
            "--seed",
            &cfg.seed.to_string(),
            "--bucket-kb",
            "64",
            "--deadline-ms",
            "60000",
            "--dump-dir",
            dir.to_str().expect("utf8 dir"),
        ])
        .args(extra)
        .output()
        .expect("spawn nnl train-dist");
    assert!(
        out.status.success(),
        "train-dist --launch {world} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Load every rank's dump from `dir` as (name, dims, f32 bits) lists.
fn rank_dumps(world: usize, dir: &PathBuf) -> Vec<Vec<(String, Vec<usize>, Vec<u32>)>> {
    (0..world)
        .map(|r| {
            let path = dir.join(format!("params_rank{r}.bin"));
            read_params_dump(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("reading rank {r} dump: {e}"))
        })
        .collect()
}

/// Compute the sequential oracle on this thread and dump it.
fn reference_dump(world: usize, dir: &PathBuf) -> Vec<(String, Vec<usize>, Vec<u32>)> {
    train_distributed_reference("lenet", &job_data(), &job_cfg(), world);
    let path = dir.join("params_reference.bin");
    nnl::trainer::dump_registry_params(path.to_str().unwrap()).expect("dump reference");
    read_params_dump(path.to_str().unwrap()).expect("read reference dump")
}

#[test]
fn multiprocess_tcp_training_matches_reference_bit_for_bit() {
    for world in [2usize, 4] {
        let dir = std::env::temp_dir().join(format!("nnl_dist_it_w{world}"));
        launch_train_dist(world, &dir, &[]);
        let reference = reference_dump(world, &dir);
        assert!(!reference.is_empty(), "reference has no parameters");
        for (rank, dump) in rank_dumps(world, &dir).into_iter().enumerate() {
            assert_eq!(dump.len(), reference.len(), "world {world} rank {rank}: param count");
            for ((gn, gd, gb), (rn, rd, rb)) in dump.iter().zip(&reference) {
                assert_eq!(gn, rn, "world {world} rank {rank}: param order");
                assert_eq!(gd, rd, "world {world} rank {rank}: dims of {gn}");
                assert_eq!(
                    gb, rb,
                    "world {world} rank {rank}: '{gn}' differs from the sequential \
                     reference — the TCP ring broke bit-determinism"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn fp16_wire_is_close_to_reference_and_deterministic_across_reruns() {
    let world = 2;
    let dir_a = std::env::temp_dir().join("nnl_dist_it_fp16_a");
    let dir_b = std::env::temp_dir().join("nnl_dist_it_fp16_b");
    launch_train_dist(world, &dir_a, &["--fp16-comm"]);
    launch_train_dist(world, &dir_b, &["--fp16-comm"]);
    let runs_a = rank_dumps(world, &dir_a);
    let runs_b = rank_dumps(world, &dir_b);

    // rerun determinism: the compressed ring is still a fixed fold,
    // so two identical launches agree to the bit at every rank
    assert_eq!(runs_a, runs_b, "fp16 runs are not deterministic across reruns");
    // and all ranks within one run agree with each other
    for (rank, dump) in runs_a.iter().enumerate() {
        assert_eq!(dump, &runs_a[0], "fp16 rank {rank} disagrees with rank 0");
    }

    // accuracy: within 1e-3 of the exact-f32 sequential reference
    let reference = reference_dump(world, &dir_a);
    let mut max_diff = 0.0f32;
    for ((gn, _, gb), (rn, _, rb)) in runs_a[0].iter().zip(&reference) {
        assert_eq!(gn, rn, "param order");
        for (g, r) in gb.iter().zip(rb) {
            let d = (f32::from_bits(*g) - f32::from_bits(*r)).abs();
            if d > max_diff {
                max_diff = d;
            }
        }
    }
    assert!(max_diff <= 1e-3, "fp16 wire drifted {max_diff} from the f32 reference");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

// ----------------------------------------------------------- codecs

#[test]
fn seg_codec_roundtrips_and_survives_hostile_bytes() {
    use nnl::comm::net::{decode_seg, encode_seg};
    use nnl::comm::ring::{Msg, MsgKind, Wire};
    prop::check(
        0xD15C0,
        300,
        |rng: &mut Rng| {
            let n = rng.below(64);
            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let kind = match rng.below(3) {
                0 => MsgKind::Partial,
                1 => MsgKind::Final,
                _ => MsgKind::Bcast,
            };
            let fp16 = rng.below(2) == 0;
            let wire = if fp16 {
                Wire::F16(data.iter().map(|v| nnl::utils::half::f32_to_f16_bits(*v)).collect())
            } else {
                Wire::F32(data)
            };
            let msg = Msg { kind, op: rng.below(1000) as u64, seg: rng.below(16) as u32, data: wire };
            let mutation = rng.below(4);
            let seed = rng.below(u32::MAX as usize) as u64;
            (msg, mutation, seed)
        },
        |(msg, mutation, seed)| {
            let enc = encode_seg(msg);
            // clean roundtrip first
            match decode_seg(&enc) {
                Ok(back) if &back == msg => {}
                Ok(back) => return Err(format!("roundtrip changed message: {back:?}")),
                Err(e) => return Err(format!("clean frame rejected: {e}")),
            }
            // hostile variants must return typed errors or valid
            // messages — never panic, never trust a length claim
            let mut bad = enc.clone();
            match mutation {
                0 => bad.truncate((*seed as usize) % bad.len().max(1)),
                1 => nnl::faults::flip_bytes(*seed, &mut bad),
                2 => {
                    // hostile element-count claim (offset 16..20)
                    if bad.len() >= 20 {
                        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
                    }
                }
                _ => bad.extend_from_slice(&[0xAB; 7]),
            }
            let _ = decode_seg(&bad); // Ok or Err both fine; no panic
            Ok(())
        },
    );
}

#[test]
fn bucket_plan_partitions_any_size_list() {
    use nnl::comm::plan_buckets;
    prop::check(
        0xB0C4,
        200,
        |rng: &mut Rng| {
            let sizes: Vec<usize> = (0..rng.below(30)).map(|_| rng.below(10_000)).collect();
            let cap = (1 + rng.below(8192)) * 4;
            (sizes, cap)
        },
        |(sizes, cap)| {
            let plan = plan_buckets(sizes, *cap);
            let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
            seen.sort_unstable();
            if seen != (0..sizes.len()).collect::<Vec<_>>() {
                return Err(format!("not a partition of 0..{}: {seen:?}", sizes.len()));
            }
            for b in &plan {
                let elems: usize = b.iter().map(|&i| sizes[i]).sum();
                if b.is_empty() || (elems * 4 > *cap && b.len() > 1) {
                    return Err(format!("bad bucket {b:?}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ chaos

/// Under injected receive faults, every rank of a TCP world gets a
/// typed `CommError` well inside the deadline — nobody hangs, nobody
/// panics. (`--features chaos` only; the schedule is process-global,
/// so this test arms and disarms it around the run.)
#[cfg(feature = "chaos")]
#[test]
fn chaos_dropped_peer_is_a_typed_error_at_every_rank() {
    use nnl::comm::{Collective, CommError, NetCommunicator, NetOptions};
    use nnl::faults::{self, Schedule};
    use std::time::{Duration, Instant};

    faults::install(Schedule::parse("comm.recv:ioerr:1.0", 11).unwrap());
    let world = 3;
    let opts = NetOptions {
        step_deadline: Duration::from_millis(500),
        connect_timeout: Duration::from_secs(5),
        ..NetOptions::default()
    };
    let listener = NetCommunicator::rendezvous_bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for rank in 1..world {
        let addr = addr.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || {
            NetCommunicator::connect(rank, world, &addr, opts)
                .and_then(|mut c| c.all_reduce_flat(&mut [1.0f32; 8], true))
        }));
    }
    let r0 = NetCommunicator::connect_with_listener(listener, world, opts)
        .and_then(|mut c| c.all_reduce_flat(&mut [1.0f32; 8], true));
    let mut results = vec![r0];
    for h in handles {
        results.push(h.join().expect("rank thread panicked"));
    }
    faults::clear();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "ranks took {:?} — the no-hang bound failed",
        t0.elapsed()
    );
    for (rank, r) in results.into_iter().enumerate() {
        match r {
            Err(CommError::Io(_)) | Err(CommError::Timeout { .. }) => {}
            other => panic!("rank {rank}: expected Io/Timeout, got {other:?}"),
        }
    }
}
