//! Bench: the deployment hot path — compiled plans vs per-call
//! interpretation, and micro-batched serving vs request-at-a-time.
//!
//! `interpreter::run` pays a per-request tax (graph re-validation,
//! name hashing, parameter re-binding) that `nnp::CompiledNet` moves
//! to load time; `serve::Server` then amortises per-layer dispatch
//! across micro-batches. The measurement harness itself lives in
//! `serve::bench_throughput` (shared with `nnl bench-serve`), mirroring
//! DLL's point that planned CPU inference leaves substantial headroom
//! over naive per-call execution.

use std::time::Duration;

use nnl::models::zoo;
use nnl::serve::{bench_throughput, ServeConfig};

fn main() {
    for (model, requests) in [("mlp", 256usize), ("lenet", 64usize)] {
        let (net, params) = zoo::export_eval(model, 3);
        let cfg = ServeConfig {
            workers: 4,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 0,
        };
        let report = bench_throughput(&net, &params, requests, &cfg)
            .unwrap_or_else(|e| panic!("{model}: {e}"));
        print!("{report}");
        println!();
    }
}
