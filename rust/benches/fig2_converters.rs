//! Bench: Figure 2 — converter fabric cost: conversion time per format
//! and inference parity/latency of each deployed form.

use std::collections::HashMap;

use nnl::converters::{frozen, nnb, onnx_lite};
use nnl::models::{build_model, Gb};
use nnl::nnp::{interpreter, Nnp};
use nnl::parametric as PF;
use nnl::tensor::{NdArray, Rng};
use nnl::utils::bench::{bench, table};

fn main() {
    // model under conversion: lenet (conv net exercises every format)
    PF::clear_parameters();
    PF::seed_parameter_rng(4);
    let mut g = Gb::new("lenet", false);
    let x = g.input("x", &[4, 1, 28, 28]);
    let logits = build_model(&mut g, "lenet", &x, 10);
    let def = g.finish(&[&logits]);
    let params: Vec<(String, NdArray)> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let nnp = Nnp::from_network(def.clone(), params.clone());
    let pm = nnp.param_map();
    let mut rng = Rng::new(0);
    let input = rng.randn(&[4, 1, 28, 28], 1.0);
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), input);

    // conversion cost
    let conv_rows = vec![
        bench("convert: NNP -> ONNX-lite", 1, 10, || {
            let m = onnx_lite::to_onnx(&def, &pm).unwrap();
            std::hint::black_box(onnx_lite::save_bytes(&m));
        }),
        bench("convert: NNP -> NNB", 1, 10, || {
            std::hint::black_box(nnb::to_nnb(&def, &params));
        }),
        bench("convert: NNP -> frozen", 1, 10, || {
            let fg = frozen::freeze(&def, &pm).unwrap();
            std::hint::black_box(frozen::save_bytes(&fg));
        }),
    ];
    print!("{}", table("Figure 2a: conversion cost (lenet)", &conv_rows));

    // deployed inference latency, all formats (must agree numerically)
    let reference = interpreter::run(&def, &inputs, &pm).unwrap().remove(0);
    let onnx = onnx_lite::to_onnx(&def, &pm).unwrap();
    let (onet, oparams) = onnx_lite::from_onnx(&onnx).unwrap();
    let opm: HashMap<String, NdArray> = oparams.into_iter().collect();
    let nnb_bytes = nnb::to_nnb(&def, &params);
    let fg = frozen::freeze(&def, &pm).unwrap();

    let infer_rows = vec![
        bench("infer: NNP interpreter", 1, 10, || {
            let out = interpreter::run(&def, &inputs, &pm).unwrap();
            assert!(out[0].allclose(&reference, 1e-5, 1e-5));
        }),
        bench("infer: via ONNX roundtrip", 1, 10, || {
            let out = interpreter::run(&onet, &inputs, &opm).unwrap();
            assert!(out[0].allclose(&reference, 1e-5, 1e-5));
        }),
        bench("infer: NNB runtime (decode + run)", 1, 10, || {
            let out = nnb::run_nnb(&nnb_bytes, &inputs).unwrap();
            assert!(out[0].allclose(&reference, 1e-5, 1e-5));
        }),
        bench("infer: frozen graph", 1, 10, || {
            let out = frozen::run(&fg, &inputs).unwrap();
            assert!(out[0].allclose(&reference, 1e-5, 1e-5));
        }),
    ];
    print!("{}", table("Figure 2b: deployed inference (batch 4), numerics checked", &infer_rows));
}
