//! `cargo bench --bench plan_optimizer` — the compile-time graph
//! optimizer suite: optimized-vs-unoptimized step counts, static-plan
//! peak arena bytes, per-pass rewrite stats, and serving throughput on
//! both plans across zoo models. Same harness as `nnl bench-plan`;
//! writes `BENCH_plan.json`.

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let report = nnl::bench_plan::run(quick);
    print!("{}", report.text);
    let out = std::path::PathBuf::from("BENCH_plan.json");
    nnl::bench_plan::write_json(&out, &report.json).expect("writing bench JSON");
    println!("wrote {}", out.display());
}
