//! Bench: Table 1 — per-step training time, FP-32 vs mixed precision,
//! across backends. `cargo bench --bench table1_mixed_precision`

use nnl::data::{DataSource, SyntheticImages};
use nnl::runtime::{Manifest, StaticExecutable};
use nnl::solvers::Solver;
use nnl::tensor::NdArray;
use nnl::trainer::{train_dynamic, TrainConfig};
use nnl::utils::bench::{bench, table};
use nnl::Variable;

fn static_step_bench(
    manifest: &Manifest,
    artifact: &str,
    data: &SyntheticImages,
    scale: f32,
) -> nnl::utils::bench::Measurement {
    let exe = StaticExecutable::load(manifest, artifact).expect("load artifact");
    let params: Vec<(String, Variable)> = exe
        .spec()
        .init_params()
        .into_iter()
        .map(|(n, a)| (n, Variable::from_array(a, true)))
        .collect();
    let mut solver = Solver::momentum(0.05, 0.9);
    solver.set_parameters(&params);
    let (bx, by) = data.batch(0, 0, 1);
    let by = by.reshape(&exe.spec().data_inputs()[1].dims);
    let mut step = 0usize;
    bench(artifact, 3, 15, || {
        let mut inputs: Vec<NdArray> = params.iter().map(|(_, v)| v.data()).collect();
        inputs.push(bx.clone());
        inputs.push(by.clone());
        inputs.push(NdArray::scalar(scale));
        let out = exe.execute(&inputs).expect("execute");
        for ((_, v), g) in params.iter().zip(&out[..params.len()]) {
            v.set_grad(g.clone());
        }
        solver.scale_grad(1.0 / scale);
        solver.update();
        step += 1;
    })
}

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("run `make artifacts`");
    let data = SyntheticImages::imagenet_mini(16);

    // dynamic baseline measured through the trainer
    let cfg = TrainConfig { steps: 10, val_batches: 0, ..Default::default() };
    let dyn_report = train_dynamic("resnet18", &data, &cfg);
    let dyn_m = nnl::utils::bench::Measurement {
        name: "nnl-dynamic f32 (define-by-run)".into(),
        iters: cfg.steps,
        mean_secs: dyn_report.wall_secs / cfg.steps as f64,
        min_secs: dyn_report.wall_secs / cfg.steps as f64,
    };

    let rows = vec![
        dyn_m,
        static_step_bench(&manifest, "resnet_mini_train_jnpref_b16", &data, 1.0),
        static_step_bench(&manifest, "resnet_mini_train_f32_b16", &data, 1.0),
        static_step_bench(&manifest, "resnet_mini_train_bf16_b16", &data, 8.0),
    ];
    print!("{}", table("Table 1: ResNet-mini train step (batch 16)", &rows));
    let f32_t = rows[2].mean_secs;
    let bf16_t = rows[3].mean_secs;
    println!("mixed-precision speedup: x{:.2} (paper: x2.3–3.1 on Volta)", f32_t / bf16_t);
}
