//! Bench: Table 2 — per-step training time of the ResNet-variant zoo.

use nnl::data::SyntheticImages;
use nnl::trainer::{train_dynamic, TrainConfig};
use nnl::utils::bench::{table, Measurement};

fn main() {
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps: 8, val_batches: 0, ..Default::default() };
    let rows: Vec<Measurement> =
        ["resnet18", "resnet50", "resnext50", "se_resnet50", "se_resnext50"]
            .iter()
            .map(|m| {
                let r = train_dynamic(m, &data, &cfg);
                Measurement {
                    name: m.to_string(),
                    iters: cfg.steps,
                    mean_secs: r.wall_secs / cfg.steps as f64,
                    min_secs: r.wall_secs / cfg.steps as f64,
                }
            })
            .collect();
    print!("{}", table("Table 2: ResNet variants, train step (batch 8)", &rows));
    let inc = rows.windows(2).filter(|w| w[1].mean_secs > w[0].mean_secs).count();
    println!("monotone-time pairs: {inc}/4 (paper shape: 4/4)");
}
