//! Bench: tape hot-path input gathering.
//!
//! `Variable::forward()` / `backward()` hand every node's input arrays
//! to its closures. Before the copy-on-write refactor each of those was
//! a deep `Vec<f32>` copy per node per step; now it is an O(1) `Arc`
//! bump through a `with_data` borrow. This bench reports the delta two
//! ways: the raw clone cost (deep copy vs COW handle) and a full
//! MLP train-step loop that exercises the real hot path.

use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::models::{build_model, Gb};
use nnl::parametric as PF;
use nnl::tensor::NdArray;
use nnl::utils::bench::{bench, table};
use nnl::Variable;

fn main() {
    // --- microbench: what one per-node input gather costs now
    let big = NdArray::zeros(&[256, 256]);
    let cow_clone = bench("NdArray clone (COW handle, 256x256)", 10, 1000, || {
        let c = big.clone();
        std::hint::black_box(c.dims()[0]);
    });
    let deep_copy = bench("explicit deep copy (to_vec, 256x256)", 10, 1000, || {
        let c = NdArray::from_vec(&[256, 256], big.data().to_vec());
        std::hint::black_box(c.dims()[0]);
    });

    // --- macro: reused-graph MLP train step (forward + backward),
    //     the exact loop the old per-node deep clones sat inside
    PF::clear_parameters();
    PF::seed_parameter_rng(0);
    let data = SyntheticImages::new(10, 1, 8, 32, 1);
    let (bx, by) = data.batch(0, 0, 1);
    let bx = bx.reshape(&[32, 64]);
    let mut g = Gb::new("mlp", true);
    let xt = g.input("x", &[32, 64]);
    let logits = build_model(&mut g, "mlp", &xt, 10);
    let y = Variable::from_array(by.reshape(&[32, 1]), false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
    let params = PF::get_parameters();
    let train_step = bench("MLP train step (forward + backward)", 3, 30, || {
        xt.var.set_data(bx.clone());
        loss.forward();
        for (_, p) in &params {
            p.zero_grad();
        }
        loss.backward();
    });

    let rows = vec![cow_clone, deep_copy, train_step];
    print!(
        "{}",
        table("Tape hot path: COW input gathering vs deep copies", &rows)
    );
    println!(
        "per-gather saving: deep copy is x{:.0} the cost of the COW handle",
        rows[1].mean_secs / rows[0].mean_secs.max(1e-12)
    );
}
