//! Bench: the TCP serving front end under offered load — p50/p99
//! latency vs offered rps, micro-batched vs unbatched, f32 vs int8,
//! over a real loopback socket speaking the binary protocol.
//!
//! The harness lives in `nnl::bench_serve` (shared with
//! `nnl bench-serve --net`); this binary prints the table and writes
//! `BENCH_serve.json`. Pass `--quick` for the CI smoke sizing.

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let report = nnl::bench_serve::run(quick);
    print!("{}", report.text);
    std::fs::write("BENCH_serve.json", report.json.to_string_pretty())
        .expect("writing BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json");
}
