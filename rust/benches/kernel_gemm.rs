//! Bench: the tiled, multi-threaded kernel floor vs the pre-PR naive
//! loops — GEMM GFLOP/s (naive vs packed tiled, single- and
//! multi-thread), the `NNL_THREADS` scaling curve, per-ISA f32/int8
//! microkernel tiers (scalar vs the dispatched SIMD tier at equal
//! threads, with detected CPU features and the `simd_no_worse`
//! acceptance bit), fused-conv step time, compiled-plan serving
//! throughput and the tape train-step hot path. The harness lives in
//! `nnl::bench_kernels` (shared with `nnl bench-kernels`); results
//! land in `BENCH_kernels.json`.

fn main() {
    let report = nnl::bench_kernels::run(false);
    print!("{}", report.text);
    let path = std::path::Path::new("BENCH_kernels.json");
    nnl::bench_kernels::write_json(path, &report.json).expect("writing BENCH_kernels.json");
    println!("wrote {}", path.display());
}
