//! Bench: Table 3 — per-step training time of the lightweight zoo
//! (MobileNetV3, EfficientNet-B0..B3).

use nnl::data::SyntheticImages;
use nnl::trainer::{train_dynamic, TrainConfig};
use nnl::utils::bench::{table, Measurement};

fn main() {
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps: 8, val_batches: 0, ..Default::default() };
    let rows: Vec<Measurement> = [
        "mobilenet_v3_small",
        "mobilenet_v3_large",
        "efficientnet_b0",
        "efficientnet_b1",
        "efficientnet_b2",
        "efficientnet_b3",
    ]
    .iter()
    .map(|m| {
        let r = train_dynamic(m, &data, &cfg);
        Measurement {
            name: m.to_string(),
            iters: cfg.steps,
            mean_secs: r.wall_secs / cfg.steps as f64,
            min_secs: r.wall_secs / cfg.steps as f64,
        }
    })
    .collect();
    print!("{}", table("Table 3: lightweight models, train step (batch 8)", &rows));
    let eff: Vec<f64> = rows[2..].iter().map(|r| r.mean_secs).collect();
    let inc = eff.windows(2).filter(|w| w[1] > w[0]).count();
    println!("EfficientNet compound-scaling time pairs increasing: {inc}/3 (paper: 3/3)");
}
