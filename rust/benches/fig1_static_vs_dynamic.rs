//! Bench: Figure 1 — static vs dynamic computation graphs. The paper's
//! claim: static graphs trade flexibility for speed ("the computation
//! speed is expected to be fast"). Measured as MLP train-step
//! throughput on identical workloads, plus graph re-use overhead.

use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::models::{build_model, Gb};
use nnl::parametric as PF;
use nnl::runtime::{Manifest, StaticExecutable};
use nnl::solvers::Solver;
use nnl::tensor::NdArray;
use nnl::utils::bench::{bench, table};
use nnl::Variable;

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("run `make artifacts`");
    let data = SyntheticImages::new(10, 1, 8, 32, 1);
    let (bx, by) = data.batch(0, 0, 1);
    let bx = bx.reshape(&[32, 64]);

    // --- dynamic: define-by-run, rebuild the graph every iteration
    PF::clear_parameters();
    PF::seed_parameter_rng(0);
    let dyn_rebuild = bench("dynamic (graph rebuilt per step)", 2, 20, || {
        let x = Variable::from_array(bx.clone(), false);
        let mut g = Gb::new("mlp", true);
        let xt = g.input("x", &[32, 64]);
        xt.var.set_data(x.data());
        let logits = build_model(&mut g, "mlp", &xt, 10);
        let y = Variable::from_array(by.reshape(&[32, 1]), false);
        let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
        loss.backward();
    });

    // --- dynamic with static-style reuse (Figure 1 left: define once)
    PF::clear_parameters();
    PF::seed_parameter_rng(0);
    let mut g = Gb::new("mlp", true);
    let xt = g.input("x", &[32, 64]);
    let logits = build_model(&mut g, "mlp", &xt, 10);
    let y = Variable::from_array(by.reshape(&[32, 1]), false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
    let params = PF::get_parameters();
    let dyn_reuse = bench("dynamic (graph reused, forward())", 2, 20, || {
        xt.var.set_data(bx.clone());
        loss.forward();
        for (_, p) in &params {
            p.zero_grad();
        }
        loss.backward();
    });

    // --- static: AOT HLO through PJRT
    let exe = StaticExecutable::load(&manifest, "mlp_train_f32_b32").expect("artifact");
    let sparams: Vec<(String, Variable)> = exe
        .spec()
        .init_params()
        .into_iter()
        .map(|(n, a)| (n, Variable::from_array(a, true)))
        .collect();
    let mut solver = Solver::sgd(0.05);
    solver.set_parameters(&sparams);
    let static_m = bench("static (AOT HLO via PJRT)", 2, 20, || {
        let mut inputs: Vec<NdArray> = sparams.iter().map(|(_, v)| v.data()).collect();
        inputs.push(bx.clone());
        inputs.push(by.clone());
        inputs.push(NdArray::scalar(1.0));
        let out = exe.execute(&inputs).expect("execute");
        for ((_, v), g) in sparams.iter().zip(&out[..sparams.len()]) {
            v.set_grad(g.clone());
        }
        solver.update();
    });

    let rows = vec![dyn_rebuild, dyn_reuse, static_m];
    print!("{}", table("Figure 1: static vs dynamic graphs (MLP train step, batch 32)", &rows));
    println!(
        "static speedup over dynamic-rebuild: x{:.2}",
        rows[0].mean_secs / rows[2].mean_secs
    );
}
