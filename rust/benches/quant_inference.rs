//! `cargo bench --bench quant_inference` — the int8 quantization
//! suite: fp32-vs-int8 GEMM throughput at equal thread counts, zoo
//! top-1 agreement, NNB1-vs-NNB2 artifact bytes, and per-request
//! serving throughput. Same harness as `nnl bench-quant`; writes
//! `BENCH_quant.json`.

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let report = nnl::bench_quant::run(quick);
    print!("{}", report.text);
    let out = std::path::PathBuf::from("BENCH_quant.json");
    nnl::bench_quant::write_json(&out, &report.json).expect("writing bench JSON");
    println!("wrote {}", out.display());
}
