//! Bench: Figure 3 — distributed data-parallel scaling: aggregate
//! sample throughput at 1/2/4 simulated devices. The paper's claim is
//! "efficient distributed training over multiple GPUs"; the shape to
//! reproduce is near-linear aggregate throughput growth.

use nnl::data::SyntheticImages;
use nnl::trainer::{train_distributed, train_dynamic, TrainConfig};
use nnl::utils::bench::{table, Measurement};

fn main() {
    let steps = 10;
    let cfg = TrainConfig { steps, val_batches: 0, ..Default::default() };
    let batch = 8;
    let mut rows = Vec::new();
    let mut throughputs = Vec::new();
    for world in [1usize, 2, 4] {
        let data = SyntheticImages::imagenet_mini(batch);
        let report = if world == 1 {
            train_dynamic("resnet18", &data, &cfg)
        } else {
            train_distributed("resnet18", data, &cfg, world)
        };
        // aggregate throughput: world * batch samples per step
        let samples_per_sec = (steps * world * batch) as f64 / report.wall_secs;
        throughputs.push(samples_per_sec);
        rows.push(Measurement {
            name: format!("{world} device(s): {samples_per_sec:.0} samples/s aggregate"),
            iters: steps,
            mean_secs: report.wall_secs / steps as f64,
            min_secs: report.wall_secs / steps as f64,
        });
    }
    print!("{}", table("Figure 3: data-parallel scaling (resnet18, batch 8/device)", &rows));
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "scaling efficiency: 2dev x{:.2}, 4dev x{:.2} (ideal 2.0 / 4.0; \
         physical cores = {cores}, so the achievable ceiling is x{:.1} — \
         on a single-core testbed this measures communicator overhead, \
         and the >=1.0 ratios show it is small)",
        throughputs[1] / throughputs[0],
        throughputs[2] / throughputs[0],
        cores.min(4) as f64,
    );
}
